//! Capacity-bounded LRU map (an in-tree substitute for the `lru` crate).
//!
//! Generalizes the explorer's per-run memoization cache into a shared
//! structure usable by both the explorer (`explore::explore`, unbounded —
//! a run never revisits enough keys to need eviction) and the daemon's
//! cross-request result cache (`daemon::Service`, bounded). The hit path is
//! O(1): a `HashMap` from key to slot index plus an index-linked
//! doubly-linked recency list over a slab of nodes — no allocation or
//! shifting on `get`, and eviction pops the list tail.

use std::collections::HashMap;
use std::hash::Hash;

/// Sentinel index for "no node" (the list ends).
const NIL: usize = usize::MAX;

struct Node<K, V> {
    key: K,
    val: V,
    prev: usize,
    next: usize,
}

/// A least-recently-used map: `get` and `insert` promote the entry to
/// most-recently-used; inserting into a full bounded map evicts the least
/// recently used entry.
pub struct Lru<K, V> {
    /// `None` = unbounded (never evicts); `Some(n)` holds at most `n`.
    cap: Option<usize>,
    map: HashMap<K, usize>,
    nodes: Vec<Node<K, V>>,
    /// Most-recently-used slot (NIL when empty).
    head: usize,
    /// Least-recently-used slot (NIL when empty).
    tail: usize,
    /// Recycled slots from evictions.
    free: Vec<usize>,
}

impl<K: Eq + Hash + Clone, V> Lru<K, V> {
    /// A bounded map holding at most `capacity` entries (clamped to >= 1).
    pub fn new(capacity: usize) -> Lru<K, V> {
        Lru {
            cap: Some(capacity.max(1)),
            map: HashMap::new(),
            nodes: Vec::new(),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
        }
    }

    /// An unbounded map (never evicts): plain memoization with the same
    /// API, the explorer's per-run cache.
    pub fn unbounded() -> Lru<K, V> {
        Lru { cap: None, ..Lru::new(1) }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries are live.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The bound, or `None` for an unbounded map.
    pub fn capacity(&self) -> Option<usize> {
        self.cap
    }

    /// True when `key` is present. Does **not** promote the entry (a pure
    /// membership probe, like `HashMap::contains_key`).
    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Borrow the value without promoting the entry.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|&i| &self.nodes[i].val)
    }

    /// Borrow the value and promote the entry to most-recently-used.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let &i = self.map.get(key)?;
        self.detach(i);
        self.push_front(i);
        Some(&self.nodes[i].val)
    }

    /// Insert or replace. Replacing returns the previous value; inserting
    /// into a full bounded map silently evicts the least-recently-used
    /// entry first. The written entry becomes most-recently-used.
    pub fn insert(&mut self, key: K, val: V) -> Option<V> {
        if let Some(&i) = self.map.get(&key) {
            let old = std::mem::replace(&mut self.nodes[i].val, val);
            self.detach(i);
            self.push_front(i);
            return Some(old);
        }
        if let Some(cap) = self.cap {
            while self.map.len() >= cap {
                self.evict_tail();
            }
        }
        let node = Node { key: key.clone(), val, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.map.insert(key, i);
        self.push_front(i);
        None
    }

    /// Drop every entry (capacity is kept).
    pub fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }

    /// Unlink slot `i` from the recency list.
    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.nodes[i].prev, self.nodes[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.nodes[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.nodes[next].prev = prev;
        }
        self.nodes[i].prev = NIL;
        self.nodes[i].next = NIL;
    }

    /// Link slot `i` as the most-recently-used entry.
    fn push_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Remove the least-recently-used entry and recycle its slot.
    fn evict_tail(&mut self) {
        let t = self.tail;
        if t == NIL {
            return;
        }
        self.detach(t);
        self.map.remove(&self.nodes[t].key);
        self.free.push(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_evicts_least_recently_used() {
        let mut l: Lru<&str, i32> = Lru::new(2);
        assert_eq!(l.capacity(), Some(2));
        l.insert("a", 1);
        l.insert("b", 2);
        assert_eq!(l.len(), 2);
        // touch "a" so "b" is the LRU entry when "c" arrives
        assert_eq!(l.get(&"a"), Some(&1));
        l.insert("c", 3);
        assert_eq!(l.len(), 2);
        assert!(!l.contains(&"b"), "LRU entry must be the one evicted");
        assert_eq!(l.get(&"a"), Some(&1));
        assert_eq!(l.get(&"c"), Some(&3));
    }

    #[test]
    fn insert_promotes_and_replaces() {
        let mut l: Lru<&str, i32> = Lru::new(2);
        l.insert("a", 1);
        l.insert("b", 2);
        // rewriting "a" promotes it; "b" becomes LRU and gets evicted
        assert_eq!(l.insert("a", 10), Some(1));
        l.insert("c", 3);
        assert!(l.contains(&"a") && l.contains(&"c") && !l.contains(&"b"));
        assert_eq!(l.peek(&"a"), Some(&10));
    }

    #[test]
    fn peek_and_contains_do_not_promote() {
        let mut l: Lru<&str, i32> = Lru::new(2);
        l.insert("a", 1);
        l.insert("b", 2);
        // probes must not rescue "a" from eviction
        assert_eq!(l.peek(&"a"), Some(&1));
        assert!(l.contains(&"a"));
        l.insert("c", 3);
        assert!(!l.contains(&"a"), "peek/contains must not count as use");
    }

    #[test]
    fn unbounded_never_evicts_and_recycles_slots() {
        let mut l: Lru<usize, usize> = Lru::unbounded();
        assert_eq!(l.capacity(), None);
        for i in 0..1000 {
            l.insert(i, i * 2);
        }
        assert_eq!(l.len(), 1000);
        for i in 0..1000 {
            assert_eq!(l.get(&i), Some(&(i * 2)));
        }
        // a bounded map reuses evicted slots instead of growing the slab
        let mut b: Lru<usize, usize> = Lru::new(4);
        for i in 0..100 {
            b.insert(i, i);
        }
        assert_eq!(b.len(), 4);
        assert!(b.nodes.len() <= 5, "evicted slots must be recycled");
    }

    #[test]
    fn empty_clear_and_capacity_clamp() {
        let mut l: Lru<String, ()> = Lru::new(0);
        assert_eq!(l.capacity(), Some(1), "capacity clamps to >= 1");
        assert!(l.is_empty());
        assert_eq!(l.get(&"x".to_string()), None);
        l.insert("x".into(), ());
        l.insert("y".into(), ());
        assert_eq!(l.len(), 1);
        l.clear();
        assert!(l.is_empty() && !l.contains(&"y".to_string()));
        l.insert("z".into(), ());
        assert_eq!(l.len(), 1);
    }

    #[test]
    fn get_order_is_recency_not_insertion() {
        let mut l: Lru<i32, i32> = Lru::new(3);
        for i in [1, 2, 3] {
            l.insert(i, i);
        }
        assert!(l.get(&1).is_some()); // recency now 2,3,1 oldest-first
        l.insert(4, 4); // evicts 2
        l.insert(5, 5); // evicts 3
        assert!(l.contains(&1) && l.contains(&4) && l.contains(&5));
        assert!(!l.contains(&2) && !l.contains(&3));
    }
}
