//! Slab arena with index handles: O(1) insert/remove into a flat `Vec`
//! with a free list, so hot loops (the cluster engine's per-request state)
//! allocate nothing after warmup and never chase pointers.
//!
//! Handles are plain `u32` slot indices. Freed slots are recycled LIFO, and
//! handles carry no generation tag — this is an internal building block for
//! owners that never hold a handle across its `remove` (the cluster engine
//! drops every handle exactly when the request finishes). The arena tracks
//! its peak occupancy so callers can report memory high-water marks.

/// A slab of `T` with `u32` handles, a LIFO free list, and a peak-occupancy
/// high-water mark.
///
/// ```
/// use dfmodel::util::arena::Arena;
/// let mut a = Arena::new();
/// let h = a.insert("hello");
/// assert_eq!(a[h], "hello");
/// assert_eq!(a.remove(h), "hello");
/// assert_eq!(a.len(), 0);
/// assert_eq!(a.peak(), 1);
/// ```
#[derive(Debug, Clone, Default)]
pub struct Arena<T> {
    slots: Vec<Option<T>>,
    free: Vec<u32>,
    live: usize,
    peak: usize,
}

impl<T> Arena<T> {
    /// An empty arena.
    pub fn new() -> Self {
        Arena { slots: Vec::new(), free: Vec::new(), live: 0, peak: 0 }
    }

    /// An empty arena with room for `n` values before any reallocation.
    pub fn with_capacity(n: usize) -> Self {
        Arena { slots: Vec::with_capacity(n), free: Vec::with_capacity(n), live: 0, peak: 0 }
    }

    /// Store `v`, reusing a freed slot when one exists, and return its
    /// handle. Panics if the arena ever exceeds `u32::MAX` slots.
    pub fn insert(&mut self, v: T) -> u32 {
        self.live += 1;
        self.peak = self.peak.max(self.live);
        match self.free.pop() {
            Some(h) => {
                self.slots[h as usize] = Some(v);
                h
            }
            None => {
                let h = u32::try_from(self.slots.len()).expect("arena exceeds u32::MAX slots");
                self.slots.push(Some(v));
                h
            }
        }
    }

    /// Borrow the value behind `h`. Panics on a freed or unknown handle.
    pub fn get(&self, h: u32) -> &T {
        self.slots[h as usize].as_ref().expect("arena handle used after remove")
    }

    /// Mutably borrow the value behind `h`. Panics on a freed or unknown
    /// handle.
    pub fn get_mut(&mut self, h: u32) -> &mut T {
        self.slots[h as usize].as_mut().expect("arena handle used after remove")
    }

    /// Remove and return the value behind `h`, recycling the slot. Panics
    /// on a freed or unknown handle.
    pub fn remove(&mut self, h: u32) -> T {
        let v = self.slots[h as usize].take().expect("arena handle used after remove");
        self.free.push(h);
        self.live -= 1;
        v
    }

    /// Live values currently stored.
    pub fn len(&self) -> usize {
        self.live
    }

    /// True when no values are live.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Highest number of values ever live at once — the arena's memory
    /// high-water mark in units of `T`.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Allocated slots (live + recycled) — the arena's true footprint.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

impl<T> std::ops::Index<u32> for Arena<T> {
    type Output = T;
    fn index(&self, h: u32) -> &T {
        self.get(h)
    }
}

impl<T> std::ops::IndexMut<u32> for Arena<T> {
    fn index_mut(&mut self, h: u32) -> &mut T {
        self.get_mut(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a = Arena::new();
        let h1 = a.insert(10);
        let h2 = a.insert(20);
        assert_eq!((a[h1], a[h2]), (10, 20));
        *a.get_mut(h1) += 1;
        assert_eq!(a.remove(h1), 11);
        assert_eq!(a.remove(h2), 20);
        assert!(a.is_empty());
    }

    #[test]
    fn slots_are_recycled_and_peak_tracks_high_water() {
        let mut a = Arena::new();
        let hs: Vec<u32> = (0..8).map(|i| a.insert(i)).collect();
        assert_eq!(a.capacity(), 8);
        for &h in &hs {
            a.remove(h);
        }
        // refill: no new slots, LIFO recycling
        for i in 0..8 {
            a.insert(100 + i);
        }
        assert_eq!(a.capacity(), 8, "freed slots must be reused");
        assert_eq!(a.peak(), 8);
        assert_eq!(a.len(), 8);
    }

    #[test]
    #[should_panic(expected = "after remove")]
    fn stale_handle_panics() {
        let mut a = Arena::new();
        let h = a.insert(1);
        a.remove(h);
        a.get(h);
    }
}
