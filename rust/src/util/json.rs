//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar; numbers are f64 (adequate for manifest
//! shapes and result files). Object key order is preserved so emitted
//! results diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key-insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with a useful message (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError { pos: 0, msg: format!("missing key '{key}'") })
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(kv) => kv.iter().map(|(k, _)| k.as_str()).collect(),
            _ => vec![],
        }
    }

    /// Builder helpers for emitting result files.
    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn from_map(m: &BTreeMap<String, f64>) -> Json {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }

    /// Stable-serialization clone: every object's keys sorted, recursively
    /// (arrays keep element order). `Capture`/`Explain` exports go through
    /// this so repeated runs diff cleanly regardless of insertion order.
    pub fn sorted(&self) -> Json {
        match self {
            Json::Arr(items) => Json::Arr(items.iter().map(Json::sorted).collect()),
            Json::Obj(kv) => {
                let mut kv: Vec<(String, Json)> =
                    kv.iter().map(|(k, v)| (k.clone(), v.sorted())).collect();
                kv.sort_by(|a, b| a.0.cmp(&b.0));
                Json::Obj(kv)
            }
            other => other.clone(),
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", char::from(c))))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(kv)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + char::from(c).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // Surrogate pairs: \uD800-\uDBFF followed by \uDC00-\uDFFF.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                let d = char::from(c)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                                lo = lo * 16 + d;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(char::from(c));
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if u32::from(c) < 0x20 => out.push_str(&format!("\\u{:04x}", u32::from(c))),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s, 0, false);
        f.write_str(&s)
    }
}

impl Json {
    /// Pretty-printed with 1-space indent (matches python `json.dump(indent=1)`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, 0, true);
        s
    }
}

fn write_json(v: &Json, out: &mut String, depth: usize, pretty: bool) {
    let pad = |out: &mut String, d: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..d {
                out.push(' ');
            }
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, depth + 1);
                write_json(item, out, depth + 1, pretty);
            }
            if !items.is_empty() {
                pad(out, depth);
            }
            out.push(']');
        }
        Json::Obj(kv) => {
            out.push('{');
            for (i, (k, val)) in kv.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, depth + 1);
                escape(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_json(val, out, depth + 1, pretty);
            }
            if !kv.is_empty() {
                pad(out, depth);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \u{e9} \u{1F600}");
        // raw multibyte utf-8 passes through
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"shape": [128, 256], "dtype": "f32", "ok": true, "x": 1.5}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(1024.0).to_string(), "1024");
        assert_eq!(Json::Num(1.25).to_string(), "1.25");
    }

    #[test]
    fn sorted_orders_keys_recursively_and_roundtrips() {
        let src = r#"{"z": {"b": 2, "a": 1}, "a": [{"y": 0, "x": [3, 1, 2]}], "m": true}"#;
        let v = Json::parse(src).unwrap();
        let s = v.sorted();
        assert_eq!(s.keys(), vec!["a", "m", "z"]);
        assert_eq!(s.get("z").unwrap().keys(), vec!["a", "b"]);
        let inner = &s.get("a").unwrap().as_array().unwrap()[0];
        assert_eq!(inner.keys(), vec!["x", "y"]);
        // array element order is preserved
        assert_eq!(
            inner.get("x").unwrap().as_array().unwrap(),
            &[Json::Num(3.0), Json::Num(1.0), Json::Num(2.0)]
        );
        // sorting never loses data: round-trip re-parses equal to itself
        assert_eq!(Json::parse(&s.to_string()).unwrap(), s);
        assert_eq!(Json::parse(&s.pretty()).unwrap(), s);
        // idempotent
        assert_eq!(s.sorted(), s);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "t", "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert!(v.req("missing").is_err());
        assert_eq!(v.keys(), vec!["n", "s", "a"]);
    }
}
