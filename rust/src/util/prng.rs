//! Deterministic PRNG (xoshiro256**) — `rand` is unavailable offline.
//!
//! Used by the simulated-annealing fallback optimizer, workload generators,
//! and the property-check harness. Seeded explicitly everywhere so every
//! experiment is reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics on n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free for our (small-n) uses; modulo bias is negligible
        // for n << 2^64 but we use multiply-shift to avoid it anyway.
        ((u128::from(self.next_u64()) * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/λ) via inversion — the
    /// inter-arrival distribution of the cluster workload generators.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "Rng::exp needs a positive rate");
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Log-normal with expectation `mean` and log-space standard deviation
    /// `sigma`: exp(μ + σZ) with μ = ln(mean) − σ²/2 so E[X] = mean.
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        assert!(mean > 0.0, "Rng::lognormal_mean needs a positive mean");
        let mu = mean.ln() - 0.5 * sigma * sigma;
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 20_000;
        for lambda in [0.5, 4.0] {
            let mean = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
            assert!((mean * lambda - 1.0).abs() < 0.05, "lambda={lambda} mean={mean}");
        }
        assert!((0..1000).all(|_| r.exp(2.0) >= 0.0));
    }

    #[test]
    fn lognormal_hits_requested_mean() {
        let mut r = Rng::new(21);
        let n = 40_000;
        let mean = (0..n).map(|_| r.lognormal_mean(1024.0, 0.4)).sum::<f64>() / n as f64;
        assert!((mean / 1024.0 - 1.0).abs() < 0.05, "mean={mean}");
        // sigma = 0 degenerates to the point mass
        assert!((r.lognormal_mean(128.0, 0.0) - 128.0).abs() < 1e-9);
    }

    #[test]
    fn normal_mean_and_var_roughly_standard() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
