//! Internal error handling (`anyhow` is unavailable offline — DESIGN.md
//! §Substitutions): a string-backed [`Error`], a crate-wide [`Result`]
//! alias, a [`Context`] extension for wrapping foreign errors, and the
//! [`err!`](crate::err)/[`bail!`](crate::bail)/[`ensure!`](crate::ensure)
//! macros used by the config, manifest, and runtime layers.

use std::fmt;

/// A human-readable error message, optionally wrapped with context
/// (outermost context first, like `anyhow`'s chain rendered in one line).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prefix additional context: `e.context("load manifest")` renders as
    /// `load manifest: <inner>`.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error { msg: e.to_string() }
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias (defaults to the internal [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style adapters for any displayable error.
pub trait Context<T> {
    /// Wrap the error with a fixed context prefix.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built context prefix.
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::new(format!("{ctx}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

/// Build an [`Error`] from a format string (`anyhow::anyhow!` equivalent).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

/// Return early with an [`Error`] (`anyhow::bail!` equivalent).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds
/// (`anyhow::ensure!` equivalent).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<usize> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_build_messages() {
        let e = crate::err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn context_chains() {
        let inner: std::result::Result<(), std::io::Error> = Err(
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        let e = inner.context("read manifest").unwrap_err();
        assert!(e.to_string().starts_with("read manifest: "));
        let e2 = e.context("load");
        assert!(e2.to_string().starts_with("load: read manifest: "));
    }

    #[test]
    fn conversions() {
        let e: Error = "plain".into();
        assert_eq!(e.to_string(), "plain");
        let j = crate::util::json::Json::parse("{").unwrap_err();
        let e: Error = j.into();
        assert!(e.to_string().contains("json parse error"));
    }
}
