//! Scoped parallel map over std threads (tokio/rayon unavailable offline).
//!
//! The DSE sweep evaluates hundreds of independent (workload, system)
//! configurations; `parallel_map` fans them out across available cores with
//! deterministic output ordering.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (respects `DFMODEL_THREADS`).
pub fn default_workers() -> usize {
    workers_from_override(std::env::var("DFMODEL_THREADS").ok().as_deref())
}

/// Pure policy behind [`default_workers`]: a parseable override wins
/// (clamped to >= 1), anything else falls back to available parallelism.
/// Tests exercise this path instead of mutating process-global env vars
/// (`std::env::set_var` races against concurrently-running tests).
pub fn workers_from_override(over: Option<&str>) -> usize {
    if let Some(v) = over {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item, in parallel, preserving input order in the
/// output. Work-steals via a shared atomic index so uneven item costs
/// balance across workers.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_workers(items, default_workers(), f)
}

/// `parallel_map` with an explicit worker count (1 = sequential fast path).
pub fn parallel_map_workers<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    // When a tracing capture is armed, each item's spans/metrics are
    // buffered per item (`obs::record_task`) and spliced back into the
    // calling thread's capture in item order, so the recorded span tree is
    // independent of worker count and scheduling. One atomic load when
    // tracing is off.
    let tracing = crate::obs::enabled();
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    let mut logs: Vec<Option<crate::obs::TaskLog>> = (0..n).map(|_| None).collect();
    let logs_ptr = SendPtr(logs.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            scope.spawn(move || {
                // Bind the whole wrappers (not just their fields) so the
                // closure captures the Send-able SendPtr, not the raw
                // pointer — edition-2021 disjoint capture would otherwise
                // grab the non-Send `*mut`.
                let ptr = slots_ptr;
                let lptr = logs_ptr;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: each index i is claimed by exactly one worker
                    // via the atomic counter, so the writes are disjoint; the
                    // scope guarantees both buffers outlive all workers.
                    if tracing {
                        let (r, log) = crate::obs::record_task(|| f(&items[i]));
                        unsafe {
                            *ptr.0.add(i) = Some(r);
                            *lptr.0.add(i) = Some(log);
                        }
                    } else {
                        let r = f(&items[i]);
                        unsafe {
                            *ptr.0.add(i) = Some(r);
                        }
                    }
                }
            });
        }
    });

    if tracing {
        crate::obs::splice_tasks(logs.into_iter().flatten());
    }
    slots.into_iter().map(|s| s.expect("worker missed a slot")).collect()
}

/// Pointer wrapper so the buffer pointer can cross thread bounds; safety is
/// argued at the single write site above.
struct SendPtr<T>(*mut T);
// Manual Clone/Copy: the derive would require T: Copy, but copying the
// *pointer* is always fine.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<usize> = vec![];
        let out: Vec<usize> = parallel_map(&items, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_sequential() {
        let items: Vec<usize> = (0..10).collect();
        let out = parallel_map_workers(&items, 1, |&x| x + 1);
        assert_eq!(out, (1..11).collect::<Vec<_>>());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![1, 2, 3];
        let out = parallel_map_workers(&items, 64, |&x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn uneven_work_balances() {
        let items: Vec<u64> = (0..40).collect();
        let out = parallel_map_workers(&items, 4, |&x| {
            // simulate uneven cost
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn worker_spans_splice_back_in_item_order() {
        let sess = crate::obs::start_capture();
        {
            let _p = crate::obs::span("pmap");
            let items: Vec<usize> = (0..16).collect();
            let out = parallel_map_workers(&items, 4, |&x| {
                let _s = crate::obs::span(&format!("item{x}"));
                x
            });
            assert_eq!(out, items);
        }
        let cap = crate::obs::finish_capture(sess);
        assert_eq!(cap.roots.len(), 1);
        let names: Vec<String> = cap.roots[0].children.iter().map(|c| c.name.clone()).collect();
        let want: Vec<String> = (0..16).map(|i| format!("item{i}")).collect();
        assert_eq!(names, want, "splice order must follow item order, not scheduling");
    }

    #[test]
    fn respects_env_worker_override() {
        // pure path — no process-global env mutation (set_var would race
        // against cargo's concurrent test threads)
        assert_eq!(workers_from_override(Some("2")), 2);
        assert_eq!(workers_from_override(Some("0")), 1, "override clamps to >= 1");
        let fallback = workers_from_override(None);
        assert!(fallback >= 1);
        assert_eq!(workers_from_override(Some("not-a-number")), fallback);
        assert!(default_workers() >= 1);
    }
}
