//! Scoped parallel map and a persistent worker pool over std threads
//! (tokio/rayon unavailable offline).
//!
//! The DSE sweep evaluates hundreds of independent (workload, system)
//! configurations; `parallel_map` fans them out across available cores with
//! deterministic output ordering. The daemon serves long-lived traffic;
//! [`ThreadPool`] gives it a bounded submission queue (backpressure shows
//! up as [`SubmitError::Full`], not unbounded memory growth), propagates
//! worker panics back to the submitter as an `Err`, and joins its workers
//! on [`ThreadPool::shutdown`] or drop.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Number of worker threads to use (respects `DFMODEL_THREADS`).
pub fn default_workers() -> usize {
    workers_from_override(std::env::var("DFMODEL_THREADS").ok().as_deref())
}

/// Pure policy behind [`default_workers`]: a parseable override wins
/// (clamped to >= 1), anything else falls back to available parallelism.
/// Tests exercise this path instead of mutating process-global env vars
/// (`std::env::set_var` races against concurrently-running tests).
pub fn workers_from_override(over: Option<&str>) -> usize {
    if let Some(v) = over {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item, in parallel, preserving input order in the
/// output. Work-steals via a shared atomic index so uneven item costs
/// balance across workers.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_workers(items, default_workers(), f)
}

/// `parallel_map` with an explicit worker count (1 = sequential fast path).
pub fn parallel_map_workers<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    // When a tracing capture is armed, each item's spans/metrics are
    // buffered per item (`obs::record_task`) and spliced back into the
    // calling thread's capture in item order, so the recorded span tree is
    // independent of worker count and scheduling. One atomic load when
    // tracing is off.
    let tracing = crate::obs::enabled();
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    let mut logs: Vec<Option<crate::obs::TaskLog>> = (0..n).map(|_| None).collect();
    let logs_ptr = SendPtr(logs.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            scope.spawn(move || {
                // Bind the whole wrappers (not just their fields) so the
                // closure captures the Send-able SendPtr, not the raw
                // pointer — edition-2021 disjoint capture would otherwise
                // grab the non-Send `*mut`.
                let ptr = slots_ptr;
                let lptr = logs_ptr;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: each index i is claimed by exactly one worker
                    // via the atomic counter, so the writes are disjoint; the
                    // scope guarantees both buffers outlive all workers.
                    if tracing {
                        let (r, log) = crate::obs::record_task(|| f(&items[i]));
                        unsafe {
                            *ptr.0.add(i) = Some(r);
                            *lptr.0.add(i) = Some(log);
                        }
                    } else {
                        let r = f(&items[i]);
                        unsafe {
                            *ptr.0.add(i) = Some(r);
                        }
                    }
                }
            });
        }
    });

    if tracing {
        crate::obs::splice_tasks(logs.into_iter().flatten());
    }
    slots.into_iter().map(|s| s.expect("worker missed a slot")).collect()
}

/// Pointer wrapper so the buffer pointer can cross thread bounds; safety is
/// argued at the single write site above.
struct SendPtr<T>(*mut T);
// Manual Clone/Copy: the derive would require T: Copy, but copying the
// *pointer* is always fine.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at capacity — back off and retry (the daemon
    /// maps this to HTTP 429).
    Full,
    /// The pool has shut down; no further work is accepted.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full => write!(f, "worker queue full"),
            SubmitError::Closed => write!(f, "thread pool shut down"),
        }
    }
}

/// Handle to one submitted job; redeem it with [`JobHandle::wait`].
pub struct JobHandle<R> {
    rx: mpsc::Receiver<std::thread::Result<R>>,
}

impl<R> JobHandle<R> {
    /// Block until the job finishes. A panic inside the job surfaces here
    /// as an `Err` carrying the panic message — the worker itself survives.
    pub fn wait(self) -> crate::util::error::Result<R> {
        match self.rx.recv() {
            Ok(out) => unpack(out),
            Err(_) => Err(crate::util::error::Error::new("worker dropped job result")),
        }
    }

    /// Like [`JobHandle::wait`] but gives up after `dur`, returning `None`
    /// while the job keeps running (the daemon maps this to HTTP 503).
    pub fn wait_timeout(&self, dur: Duration) -> Option<crate::util::error::Result<R>> {
        match self.rx.recv_timeout(dur) {
            Ok(out) => Some(unpack(out)),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(crate::util::error::Error::new("worker dropped job result")))
            }
        }
    }
}

fn unpack<R>(out: std::thread::Result<R>) -> crate::util::error::Result<R> {
    match out {
        Ok(r) => Ok(r),
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(crate::util::error::Error::new(format!("worker panicked: {msg}")))
        }
    }
}

/// Persistent worker pool with a bounded submission queue.
///
/// Workers pull jobs off a shared channel; each job runs under
/// `catch_unwind` so a panic is delivered to the submitter through its
/// [`JobHandle`] instead of killing the worker. Dropping the pool (or
/// calling [`ThreadPool::shutdown`]) closes the queue, lets already-queued
/// jobs drain, and joins every worker.
pub struct ThreadPool {
    tx: Option<mpsc::SyncSender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `workers` threads (clamped to >= 1) behind a queue holding at
    /// most `queue_cap` not-yet-started jobs (clamped to >= 1).
    pub fn new(workers: usize, queue_cap: usize) -> ThreadPool {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    // hold the lock only for the dequeue, never while the
                    // job runs, so workers drain the queue concurrently
                    let job = match rx.lock().expect("pool receiver poisoned").recv() {
                        Ok(job) => job,
                        Err(_) => break, // sender dropped: shutdown
                    };
                    queued.fetch_sub(1, Ordering::Relaxed);
                    job();
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers: handles, queued }
    }

    /// Jobs accepted but not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Queue `f`, blocking while the queue is full.
    pub fn submit<R, F>(&self, f: F) -> Result<JobHandle<R>, SubmitError>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        let (job, handle) = package(f);
        let tx = self.tx.as_ref().ok_or(SubmitError::Closed)?;
        self.queued.fetch_add(1, Ordering::Relaxed);
        match tx.send(job) {
            Ok(()) => Ok(handle),
            Err(_) => {
                self.queued.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Queue `f` without blocking; a full queue is the caller's problem
    /// ([`SubmitError::Full`] — the daemon's 429 path).
    pub fn try_submit<R, F>(&self, f: F) -> Result<JobHandle<R>, SubmitError>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        let (job, handle) = package(f);
        let tx = self.tx.as_ref().ok_or(SubmitError::Closed)?;
        self.queued.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(job) {
            Ok(()) => Ok(handle),
            Err(mpsc::TrySendError::Full(_)) => {
                self.queued.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Full)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.queued.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Stop accepting work, let queued jobs drain, and join every worker.
    /// Dropping the pool does the same; this form makes the join explicit.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.tx.take(); // closes the channel: workers exit after draining
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Wrap `f` in a panic-catching job plus the handle its result arrives on.
fn package<R, F>(f: F) -> (Job, JobHandle<R>)
where
    R: Send + 'static,
    F: FnOnce() -> R + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let job: Job = Box::new(move || {
        let out = catch_unwind(AssertUnwindSafe(f));
        let _ = tx.send(out); // submitter may have stopped waiting: fine
    });
    (job, JobHandle { rx })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<usize> = vec![];
        let out: Vec<usize> = parallel_map(&items, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_sequential() {
        let items: Vec<usize> = (0..10).collect();
        let out = parallel_map_workers(&items, 1, |&x| x + 1);
        assert_eq!(out, (1..11).collect::<Vec<_>>());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![1, 2, 3];
        let out = parallel_map_workers(&items, 64, |&x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn uneven_work_balances() {
        let items: Vec<u64> = (0..40).collect();
        let out = parallel_map_workers(&items, 4, |&x| {
            // simulate uneven cost
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn worker_spans_splice_back_in_item_order() {
        let sess = crate::obs::start_capture();
        {
            let _p = crate::obs::span("pmap");
            let items: Vec<usize> = (0..16).collect();
            let out = parallel_map_workers(&items, 4, |&x| {
                let _s = crate::obs::span(&format!("item{x}"));
                x
            });
            assert_eq!(out, items);
        }
        let cap = crate::obs::finish_capture(sess);
        assert_eq!(cap.roots.len(), 1);
        let names: Vec<String> = cap.roots[0].children.iter().map(|c| c.name.clone()).collect();
        let want: Vec<String> = (0..16).map(|i| format!("item{i}")).collect();
        assert_eq!(names, want, "splice order must follow item order, not scheduling");
    }

    #[test]
    fn respects_env_worker_override() {
        // pure path — no process-global env mutation (set_var would race
        // against cargo's concurrent test threads)
        assert_eq!(workers_from_override(Some("2")), 2);
        assert_eq!(workers_from_override(Some("0")), 1, "override clamps to >= 1");
        let fallback = workers_from_override(None);
        assert!(fallback >= 1);
        assert_eq!(workers_from_override(Some("not-a-number")), fallback);
        assert!(default_workers() >= 1);
    }

    #[test]
    fn pool_runs_jobs_and_returns_results() {
        let pool = ThreadPool::new(4, 16);
        let handles: Vec<_> =
            (0..20).map(|i: usize| pool.submit(move || i * 3).unwrap()).collect();
        let got: Vec<usize> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        assert_eq!(got, (0..20).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn pool_propagates_panic_and_survives() {
        let pool = ThreadPool::new(1, 4);
        let boom = pool.submit(|| -> usize { panic!("kaboom {}", 7) }).unwrap();
        let err = boom.wait().unwrap_err();
        assert!(
            err.to_string().contains("worker panicked") && err.to_string().contains("kaboom 7"),
            "got: {err}"
        );
        // the single worker must have survived the panic
        let ok = pool.submit(|| 41 + 1).unwrap();
        assert_eq!(ok.wait().unwrap(), 42);
    }

    #[test]
    fn pool_shutdown_drains_queued_jobs() {
        let done = Arc::new(AtomicUsize::new(0));
        let pool = ThreadPool::new(2, 32);
        for _ in 0..16 {
            let done = Arc::clone(&done);
            pool.submit(move || {
                std::thread::sleep(Duration::from_millis(2));
                done.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown(); // must block until every queued job ran
        assert_eq!(done.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn try_submit_reports_full_queue() {
        // 1 worker, queue of 1: occupy the worker, fill the queue, then a
        // third submission must bounce with Full
        let pool = ThreadPool::new(1, 1);
        let (started_tx, started_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let running = pool
            .try_submit(move || {
                started_tx.send(()).unwrap();
                gate_rx.recv().unwrap();
            })
            .unwrap();
        started_rx.recv().unwrap(); // worker is now busy, queue empty
        let queued = pool.try_submit(|| ()).unwrap(); // fills the queue
        assert_eq!(pool.queue_depth(), 1);
        assert_eq!(pool.try_submit(|| ()).unwrap_err(), SubmitError::Full);
        gate_tx.send(()).unwrap();
        running.wait().unwrap();
        queued.wait().unwrap();
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn wait_timeout_none_while_running() {
        let pool = ThreadPool::new(1, 4);
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let h = pool.submit(move || gate_rx.recv().unwrap()).unwrap();
        assert!(h.wait_timeout(Duration::from_millis(10)).is_none());
        gate_tx.send(()).unwrap();
        assert!(h.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }
}
