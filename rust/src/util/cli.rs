//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional ...] [--key value] [--flag]`.
//! Used by `rust/src/main.rs` and the bench binaries.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token is NOT argv[0]).
    pub fn parse_from<I, S>(tokens: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from process argv (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Parse a `host:port` listen address with errors a user can act on
/// (`SocketAddr::from_str` only says "invalid socket address syntax").
/// Accepts IPv4 (`127.0.0.1:8080`), bracketed IPv6 (`[::1]:8080`), and
/// resolvable hostnames (`localhost:8080`); port 0 asks the OS for an
/// ephemeral port.
pub fn parse_addr(s: &str) -> Result<std::net::SocketAddr, String> {
    use std::net::ToSocketAddrs;
    let s = s.trim();
    let Some((host, port)) = s.rsplit_once(':') else {
        return Err(format!("invalid address '{s}': expected host:port (e.g. 127.0.0.1:8080)"));
    };
    if host.is_empty() {
        return Err(format!("invalid address '{s}': missing host before ':'"));
    }
    let port: u16 = port.parse().map_err(|_| {
        format!("invalid address '{s}': port '{port}' is not an integer in 0..=65535")
    })?;
    // bracketed IPv6 literal: ToSocketAddrs wants the bare address
    let host = host.strip_prefix('[').and_then(|h| h.strip_suffix(']')).unwrap_or(host);
    let mut addrs = (host, port)
        .to_socket_addrs()
        .map_err(|e| format!("invalid address '{s}': cannot resolve host '{host}': {e}"))?;
    addrs.next().ok_or_else(|| format!("invalid address '{s}': host '{host}' resolved to nothing"))
}

/// Levenshtein edit distance (for "did you mean" hints).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The candidate closest to `input` by edit distance, when close enough to
/// be a plausible typo. The threshold scales with the input length (a
/// fixed cutoff would let 1-3 character garbage match everything).
pub fn suggest<'a>(input: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(usize, &'a str)> = None;
    for &c in candidates {
        let d = edit_distance(input, c);
        let better = match best {
            None => true,
            Some((bd, _)) => d < bd,
        };
        if better {
            best = Some((d, c));
        }
    }
    let limit = (input.chars().count() / 3).clamp(1, 3);
    match best {
        Some((d, c)) if d <= limit => Some(c),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse_from(["dse", "--workload", "gpt3-1t", "--chips=1024", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("dse"));
        assert_eq!(a.get("workload"), Some("gpt3-1t"));
        assert_eq!(a.get_usize("chips", 0), 1024);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = Args::parse_from(["run", "fig10", "fig11"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["fig10", "fig11"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse_from(["x", "--all"]);
        assert!(a.has_flag("all"));
        assert_eq!(a.get("all"), None);
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from::<_, String>([]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse_from(["s", "--k=v", "--n=3"]);
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn suggest_finds_near_misses() {
        let cmds = ["optimize", "simulate", "plan", "fabric", "dse"];
        assert_eq!(suggest("optimzie", &cmds), Some("optimize"));
        assert_eq!(suggest("simulat", &cmds), Some("simulate"));
        assert_eq!(suggest("pla", &cmds), Some("plan"));
        // way off: no suggestion rather than a misleading one
        assert_eq!(suggest("quantum-teleport", &cmds), None);
    }

    #[test]
    fn parse_addr_accepts_common_forms() {
        let a = parse_addr("127.0.0.1:8080").unwrap();
        assert_eq!(a.port(), 8080);
        assert!(a.ip().is_loopback());
        // port 0 = ephemeral; whitespace tolerated
        assert_eq!(parse_addr(" 127.0.0.1:0 ").unwrap().port(), 0);
        let v6 = parse_addr("[::1]:9000").unwrap();
        assert_eq!(v6.port(), 9000);
        assert!(v6.is_ipv6());
        assert_eq!(parse_addr("localhost:7777").unwrap().port(), 7777);
    }

    #[test]
    fn parse_addr_rejects_malformed_inputs() {
        let no_colon = parse_addr("8080").unwrap_err();
        assert!(no_colon.contains("expected host:port"), "got: {no_colon}");
        let no_host = parse_addr(":8080").unwrap_err();
        assert!(no_host.contains("missing host"), "got: {no_host}");
        let bad_port = parse_addr("127.0.0.1:http").unwrap_err();
        assert!(bad_port.contains("'http'") && bad_port.contains("0..=65535"), "got: {bad_port}");
        let big_port = parse_addr("127.0.0.1:70000").unwrap_err();
        assert!(big_port.contains("70000"), "got: {big_port}");
        let bad_host = parse_addr("999.999.999.999:80").unwrap_err();
        assert!(bad_host.contains("cannot resolve"), "got: {bad_host}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
