//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional ...] [--key value] [--flag]`.
//! Used by `rust/src/main.rs` and the bench binaries.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token is NOT argv[0]).
    pub fn parse_from<I, S>(tokens: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from process argv (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Levenshtein edit distance (for "did you mean" hints).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The candidate closest to `input` by edit distance, when close enough to
/// be a plausible typo. The threshold scales with the input length (a
/// fixed cutoff would let 1-3 character garbage match everything).
pub fn suggest<'a>(input: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(usize, &'a str)> = None;
    for &c in candidates {
        let d = edit_distance(input, c);
        let better = match best {
            None => true,
            Some((bd, _)) => d < bd,
        };
        if better {
            best = Some((d, c));
        }
    }
    let limit = (input.chars().count() / 3).clamp(1, 3);
    match best {
        Some((d, c)) if d <= limit => Some(c),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse_from(["dse", "--workload", "gpt3-1t", "--chips=1024", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("dse"));
        assert_eq!(a.get("workload"), Some("gpt3-1t"));
        assert_eq!(a.get_usize("chips", 0), 1024);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = Args::parse_from(["run", "fig10", "fig11"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["fig10", "fig11"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse_from(["x", "--all"]);
        assert!(a.has_flag("all"));
        assert_eq!(a.get("all"), None);
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from::<_, String>([]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse_from(["s", "--k=v", "--n=3"]);
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn suggest_finds_near_misses() {
        let cmds = ["optimize", "simulate", "plan", "fabric", "dse"];
        assert_eq!(suggest("optimzie", &cmds), Some("optimize"));
        assert_eq!(suggest("simulat", &cmds), Some("simulate"));
        assert_eq!(suggest("pla", &cmds), Some("plan"));
        // way off: no suggestion rather than a misleading one
        assert_eq!(suggest("quantum-teleport", &cmds), None);
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
