//! Unit constants and human-readable formatting for the performance model.
//!
//! Convention throughout the crate: bytes and FLOP are `f64` in base units,
//! times in seconds, bandwidths in bytes/second, compute in FLOP/second.

pub const KB: f64 = 1e3;
pub const MB: f64 = 1e6;
pub const GB: f64 = 1e9;
pub const TB: f64 = 1e12;

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

pub const GFLOPS: f64 = 1e9;
pub const TFLOPS: f64 = 1e12;
pub const PFLOPS: f64 = 1e15;

pub const US: f64 = 1e-6;
pub const MS: f64 = 1e-3;
pub const NS: f64 = 1e-9;

/// "12.3 GB/s", "1.50 TB/s" …
pub fn fmt_bw(bytes_per_s: f64) -> String {
    fmt_scaled(bytes_per_s, &[(TB, "TB/s"), (GB, "GB/s"), (MB, "MB/s"), (KB, "KB/s")], "B/s")
}

/// "640 MB", "40 GB" …
pub fn fmt_bytes(bytes: f64) -> String {
    fmt_scaled(bytes, &[(TB, "TB"), (GB, "GB"), (MB, "MB"), (KB, "KB")], "B")
}

/// "993 TFLOPS" …
pub fn fmt_flops(flops: f64) -> String {
    fmt_scaled(flops, &[(PFLOPS, "PFLOPS"), (TFLOPS, "TFLOPS"), (GFLOPS, "GFLOPS")], "FLOPS")
}

/// "1.2 ms", "3.4 us", "5.6 s" …
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

fn fmt_scaled(v: f64, scales: &[(f64, &str)], base: &str) -> String {
    for &(s, name) in scales {
        if v.abs() >= s {
            return format!("{:.3} {}", v / s, name);
        }
    }
    format!("{v:.1} {base}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_bandwidth() {
        assert_eq!(fmt_bw(900.0 * GB), "900.000 GB/s");
        assert_eq!(fmt_bw(3.0 * TB), "3.000 TB/s");
        assert_eq!(fmt_bw(12.5), "12.5 B/s");
    }

    #[test]
    fn formats_flops() {
        assert_eq!(fmt_flops(993.0 * TFLOPS), "993.000 TFLOPS");
        assert_eq!(fmt_flops(7.5 * PFLOPS), "7.500 PFLOPS");
    }

    #[test]
    fn formats_time() {
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 us");
        assert_eq!(fmt_time(150e-9), "150.0 ns");
    }

    #[test]
    fn formats_bytes() {
        assert_eq!(fmt_bytes(640.0 * MB), "640.000 MB");
        assert_eq!(fmt_bytes(40.0 * GB), "40.000 GB");
    }
}
