//! Unit constants, typed dimensional quantities, and human-readable
//! formatting for the performance model.
//!
//! Convention throughout the crate: bytes and FLOP are `f64` in base units,
//! times in seconds, bandwidths in bytes/second, compute in FLOP/second.
//! The core analytical path (`system/`, `roofline/`, `collective/`,
//! `sharding/`, `interchip/`, `pipeline/`, `explore::bound`) carries these
//! quantities in the zero-cost newtypes below so that dimension mixups
//! (bytes vs bytes/s, $ vs W) are compile errors rather than silently wrong
//! predictions. Peripheral layers (JSON serialization, figures, the graph
//! IR) stay on raw `f64` and convert at the boundary via the documented
//! escape hatches [`Bytes::new`]/[`Bytes::raw`] (and likewise for every
//! other unit type).
//!
//! # Dimensional laws
//!
//! Only dimension-correct arithmetic compiles:
//!
//! - `Bytes / BytesPerSec = Seconds` and `Bytes / Seconds = BytesPerSec`
//! - `Flop / FlopPerSec = Seconds` and `Flop / Seconds = FlopPerSec`
//! - `Seconds * BytesPerSec = Bytes` (commutative)
//! - `Seconds * FlopPerSec = Flop` (commutative)
//! - same-type `+`, `-`, `+=`, `-=`, `sum()`, `max`/`min`, ordered
//!   comparisons
//! - scalar `* f64` / `/ f64` (commutative for `*`)
//! - same-type `/` yields a dimensionless `f64` ratio
//!
//! Every wrapped operation is the identical IEEE-754 `f64` operation in the
//! identical order, so the typed refactor is bit-for-bit invisible: the
//! pinned parity tests (`tests/explore.rs`, figure pins) pass unchanged.

pub const KB: f64 = 1e3;
pub const MB: f64 = 1e6;
pub const GB: f64 = 1e9;
pub const TB: f64 = 1e12;

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

pub const GFLOPS: f64 = 1e9;
pub const TFLOPS: f64 = 1e12;
pub const PFLOPS: f64 = 1e15;

pub const US: f64 = 1e-6;
pub const MS: f64 = 1e-3;
pub const NS: f64 = 1e-9;

/// Generate a zero-cost unit newtype with same-dimension arithmetic,
/// scalar scaling, ordered comparisons, and the serialization escape
/// hatches (`new`/`raw`/`to_bits`).
macro_rules! unit_type {
    ($(#[$meta:meta])* $name:ident) => {
        $(#[$meta])*
        #[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
        pub struct $name(f64);

        impl $name {
            /// The zero quantity.
            pub const ZERO: $name = $name(0.0);

            /// Wrap a raw `f64` in base units (escape hatch for
            /// deserialization and catalog literals).
            #[inline]
            pub const fn new(v: f64) -> Self {
                Self(v)
            }

            /// Unwrap to the raw `f64` in base units (escape hatch for
            /// serialization and cross-dimension formulas such as
            /// operational intensity).
            #[inline]
            pub const fn raw(self) -> f64 {
                self.0
            }

            /// Bit pattern of the underlying `f64` (for bitwise parity
            /// pins and hash keys).
            #[inline]
            pub fn to_bits(self) -> u64 {
                self.0.to_bits()
            }

            /// Larger of the two quantities (IEEE `f64::max` semantics).
            #[inline]
            pub fn max(self, other: Self) -> Self {
                Self(self.0.max(other.0))
            }

            /// Smaller of the two quantities (IEEE `f64::min` semantics).
            #[inline]
            pub fn min(self, other: Self) -> Self {
                Self(self.0.min(other.0))
            }

            /// Magnitude of the quantity.
            #[inline]
            pub fn abs(self) -> Self {
                Self(self.0.abs())
            }

            /// True unless the quantity is NaN or infinite.
            #[inline]
            pub fn is_finite(self) -> bool {
                self.0.is_finite()
            }
        }

        impl std::ops::Add for $name {
            type Output = Self;
            #[inline]
            fn add(self, o: Self) -> Self {
                Self(self.0 + o.0)
            }
        }

        impl std::ops::Sub for $name {
            type Output = Self;
            #[inline]
            fn sub(self, o: Self) -> Self {
                Self(self.0 - o.0)
            }
        }

        impl std::ops::Neg for $name {
            type Output = Self;
            #[inline]
            fn neg(self) -> Self {
                Self(-self.0)
            }
        }

        impl std::ops::AddAssign for $name {
            #[inline]
            fn add_assign(&mut self, o: Self) {
                self.0 += o.0;
            }
        }

        impl std::ops::SubAssign for $name {
            #[inline]
            fn sub_assign(&mut self, o: Self) {
                self.0 -= o.0;
            }
        }

        impl std::ops::Mul<f64> for $name {
            type Output = Self;
            #[inline]
            fn mul(self, s: f64) -> Self {
                Self(self.0 * s)
            }
        }

        impl std::ops::Mul<$name> for f64 {
            type Output = $name;
            #[inline]
            fn mul(self, u: $name) -> $name {
                $name(self * u.0)
            }
        }

        impl std::ops::Div<f64> for $name {
            type Output = Self;
            #[inline]
            fn div(self, s: f64) -> Self {
                Self(self.0 / s)
            }
        }

        impl std::ops::MulAssign<f64> for $name {
            #[inline]
            fn mul_assign(&mut self, s: f64) {
                self.0 *= s;
            }
        }

        impl std::ops::DivAssign<f64> for $name {
            #[inline]
            fn div_assign(&mut self, s: f64) {
                self.0 /= s;
            }
        }

        /// Same-dimension ratio: dimensionless.
        impl std::ops::Div for $name {
            type Output = f64;
            #[inline]
            fn div(self, o: Self) -> f64 {
                self.0 / o.0
            }
        }

        impl std::iter::Sum for $name {
            fn sum<I: Iterator<Item = Self>>(it: I) -> Self {
                Self(it.map(|u| u.0).sum())
            }
        }

        impl<'a> std::iter::Sum<&'a $name> for $name {
            fn sum<I: Iterator<Item = &'a Self>>(it: I) -> Self {
                Self(it.map(|u| u.0).sum())
            }
        }
    };
}

/// `A / B = C` dimensional law.
macro_rules! unit_law_div {
    ($a:ident / $b:ident = $c:ident) => {
        impl std::ops::Div<$b> for $a {
            type Output = $c;
            #[inline]
            fn div(self, o: $b) -> $c {
                $c(self.0 / o.0)
            }
        }
    };
}

/// `A * B = C` dimensional law (both operand orders).
macro_rules! unit_law_mul {
    ($a:ident * $b:ident = $c:ident) => {
        impl std::ops::Mul<$b> for $a {
            type Output = $c;
            #[inline]
            fn mul(self, o: $b) -> $c {
                $c(self.0 * o.0)
            }
        }

        impl std::ops::Mul<$a> for $b {
            type Output = $c;
            #[inline]
            fn mul(self, o: $a) -> $c {
                $c(self.0 * o.0)
            }
        }
    };
}

unit_type! {
    /// A data size in bytes.
    Bytes
}
unit_type! {
    /// A bandwidth in bytes per second.
    BytesPerSec
}
unit_type! {
    /// A floating-point operation count.
    Flop
}
unit_type! {
    /// A compute rate in FLOP per second.
    FlopPerSec
}
unit_type! {
    /// A duration in seconds.
    Seconds
}
unit_type! {
    /// An electrical power in watts.
    Watts
}
unit_type! {
    /// A price in US dollars.
    Dollars
}

unit_law_div!(Bytes / BytesPerSec = Seconds);
unit_law_div!(Bytes / Seconds = BytesPerSec);
unit_law_div!(Flop / FlopPerSec = Seconds);
unit_law_div!(Flop / Seconds = FlopPerSec);
unit_law_mul!(Seconds * BytesPerSec = Bytes);
unit_law_mul!(Seconds * FlopPerSec = Flop);

impl std::fmt::Display for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&fmt_bytes(self.0))
    }
}

impl std::fmt::Display for BytesPerSec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&fmt_bw(self.0))
    }
}

impl std::fmt::Display for FlopPerSec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&fmt_flops(self.0))
    }
}

impl std::fmt::Display for Seconds {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&fmt_time(self.0))
    }
}

/// "12.3 GB/s", "1.50 TB/s" …
pub fn fmt_bw(bytes_per_s: f64) -> String {
    fmt_scaled(bytes_per_s, &[(TB, "TB/s"), (GB, "GB/s"), (MB, "MB/s"), (KB, "KB/s")], "B/s")
}

/// "640 MB", "40 GB" …
pub fn fmt_bytes(bytes: f64) -> String {
    fmt_scaled(bytes, &[(TB, "TB"), (GB, "GB"), (MB, "MB"), (KB, "KB")], "B")
}

/// "993 TFLOPS" …
pub fn fmt_flops(flops: f64) -> String {
    fmt_scaled(flops, &[(PFLOPS, "PFLOPS"), (TFLOPS, "TFLOPS"), (GFLOPS, "GFLOPS")], "FLOPS")
}

/// "1.2 ms", "3.4 us", "5.6 s" …
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

fn fmt_scaled(v: f64, scales: &[(f64, &str)], base: &str) -> String {
    for &(s, name) in scales {
        // Select the scale by the magnitude as it will appear after the
        // 3-decimal rounding, so 999.9995 GB/s promotes to "1.000 TB/s"
        // instead of rendering as "1000.000 GB/s".
        let scaled = v / s;
        if (scaled.abs() * 1e3).round() >= 1e3 {
            return format!("{scaled:.3} {name}");
        }
    }
    format!("{v:.1} {base}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_bandwidth() {
        assert_eq!(fmt_bw(900.0 * GB), "900.000 GB/s");
        assert_eq!(fmt_bw(3.0 * TB), "3.000 TB/s");
        assert_eq!(fmt_bw(12.5), "12.5 B/s");
    }

    #[test]
    fn formats_flops() {
        assert_eq!(fmt_flops(993.0 * TFLOPS), "993.000 TFLOPS");
        assert_eq!(fmt_flops(7.5 * PFLOPS), "7.500 PFLOPS");
    }

    #[test]
    fn formats_time() {
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 us");
        assert_eq!(fmt_time(150e-9), "150.0 ns");
    }

    #[test]
    fn formats_bytes() {
        assert_eq!(fmt_bytes(640.0 * MB), "640.000 MB");
        assert_eq!(fmt_bytes(40.0 * GB), "40.000 GB");
    }

    #[test]
    fn boundary_rounding_promotes_to_next_scale() {
        // 999.9995 GB/s rounds to 1000.000 at 3 decimals — must promote.
        assert_eq!(fmt_bw(999.9995 * GB), "1.000 TB/s");
        // just below the promotion point stays at the smaller scale
        assert_eq!(fmt_bw(999.4 * GB), "999.400 GB/s");
        // exact boundary
        assert_eq!(fmt_bw(1000.0 * GB), "1.000 TB/s");
        // the same promotion applies to the base unit -> first scale edge
        assert_eq!(fmt_bytes(999.9995), "1.000 KB");
        assert_eq!(fmt_flops(999.9995 * TFLOPS), "1.000 PFLOPS");
        // negative values promote symmetrically
        assert_eq!(fmt_bw(-999.9995 * GB), "-1.000 TB/s");
    }

    #[test]
    fn typed_ratio_laws() {
        let t: Seconds = Bytes::new(10.0 * GB) / BytesPerSec::new(1.0 * GB);
        assert_eq!(t.raw(), 10.0);
        let t2: Seconds = Flop::new(8.0 * TFLOPS) / FlopPerSec::new(2.0 * TFLOPS);
        assert_eq!(t2.raw(), 4.0);
        let b: Bytes = Seconds::new(2.0) * BytesPerSec::new(3.0);
        assert_eq!(b.raw(), 6.0);
        let b2: Bytes = BytesPerSec::new(3.0) * Seconds::new(2.0);
        assert_eq!(b2.raw(), 6.0);
        let f: Flop = Seconds::new(2.0) * FlopPerSec::new(5.0);
        assert_eq!(f.raw(), 10.0);
        let bw: BytesPerSec = Bytes::new(6.0) / Seconds::new(2.0);
        assert_eq!(bw.raw(), 3.0);
        let rate: FlopPerSec = Flop::new(6.0) / Seconds::new(3.0);
        assert_eq!(rate.raw(), 2.0);
    }

    #[test]
    fn typed_scalar_and_same_dimension_ops() {
        let a = Bytes::new(4.0);
        assert_eq!((a * 2.0).raw(), 8.0);
        assert_eq!((2.0 * a).raw(), 8.0);
        assert_eq!((a / 2.0).raw(), 2.0);
        assert_eq!((a + a).raw(), 8.0);
        assert_eq!((a - a).raw(), 0.0);
        assert_eq!(a / a, 1.0);
        assert_eq!((-a).raw(), -4.0);
        let mut m = Seconds::new(1.0);
        m += Seconds::new(0.5);
        m -= Seconds::new(0.25);
        assert_eq!(m.raw(), 1.25);
        assert!(Watts::new(1.0) < Watts::new(2.0));
        assert_eq!(Dollars::new(3.0).max(Dollars::new(5.0)).raw(), 5.0);
        assert_eq!(Dollars::new(3.0).min(Dollars::new(5.0)).raw(), 3.0);
        let total: Seconds = [Seconds::new(1.0), Seconds::new(2.0)].into_iter().sum();
        assert_eq!(total.raw(), 3.0);
        let total_ref: Seconds = [Seconds::new(1.0), Seconds::new(2.0)].iter().sum();
        assert_eq!(total_ref.raw(), 3.0);
    }

    #[test]
    fn typed_ops_are_bitwise_raw_f64_ops() {
        // The newtype wrappers must be numerically invisible: the same
        // f64 expression through the typed path yields the same bits.
        let (x, y) = (1234.5678e9, 3.14159e9);
        assert_eq!((Bytes::new(x) / BytesPerSec::new(y)).to_bits(), (x / y).to_bits());
        assert_eq!((Seconds::new(x) * BytesPerSec::new(y)).to_bits(), (x * y).to_bits());
        assert_eq!((Flop::new(x) / Seconds::new(y)).to_bits(), (x / y).to_bits());
        assert_eq!((Watts::new(x) * 0.37).to_bits(), (x * 0.37).to_bits());
        assert_eq!(Bytes::new(x).to_bits(), x.to_bits());
        assert_eq!(Bytes::ZERO.raw(), 0.0);
    }

    #[test]
    fn typed_display_delegates_to_formatters() {
        assert_eq!(BytesPerSec::new(900.0 * GB).to_string(), fmt_bw(900.0 * GB));
        assert_eq!(Bytes::new(40.0 * GB).to_string(), fmt_bytes(40.0 * GB));
        assert_eq!(FlopPerSec::new(993.0 * TFLOPS).to_string(), fmt_flops(993.0 * TFLOPS));
        assert_eq!(Seconds::new(0.0025).to_string(), fmt_time(0.0025));
    }
}
