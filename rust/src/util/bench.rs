//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock of closures with warmup, reports min/mean/p50, and is
//! the engine behind `cargo bench` (the `[[bench]]` targets set
//! `harness = false` and call into this module).
//!
//! CI integration: `DFMODEL_BENCH_QUICK=1` scales every measurement down to
//! a smoke-sized run ([`quick_mode`]), and [`Runner::write_json`] emits the
//! machine-readable per-bench results the bench-regression gate merges into
//! `BENCH_*.json` and checks with [`compare_to_baseline`]
//! (`dfmodel bench-check`).

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// True when the quick CI mode is requested (`DFMODEL_BENCH_QUICK=1`).
/// The env var is read once and cached — per-call `std::env::var` reads
/// race against `set_var` in concurrently-running code (same hazard the
/// PR-1 `threadpool::workers_from_override` fix addressed).
pub fn quick_mode() -> bool {
    static QUICK: OnceLock<bool> = OnceLock::new();
    *QUICK.get_or_init(|| quick_from_env(std::env::var("DFMODEL_BENCH_QUICK").ok().as_deref()))
}

/// Pure policy behind [`quick_mode`]; tests exercise this path instead of
/// mutating process-global env vars.
pub fn quick_from_env(v: Option<&str>) -> bool {
    matches!(v, Some("1") | Some("true"))
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
    /// Optional items/s derived from the min sample (`with_throughput`).
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn line(&self) -> String {
        let mut s = format!(
            "{:<48} iters={:<4} min={:>12?} mean={:>12?} p50={:>12?}",
            self.name, self.iters, self.min, self.mean, self.p50
        );
        if let Some(t) = self.throughput {
            s.push_str(&format!(" thr={t:.1}/s"));
        }
        s
    }

    /// Attach an items-per-iteration throughput derived from the min
    /// sample (the noise-robust statistic the regression gate compares).
    pub fn with_throughput(mut self, items_per_iter: f64) -> BenchResult {
        let secs = self.min.as_secs_f64();
        if secs > 0.0 {
            self.throughput = Some(items_per_iter / secs);
        }
        self
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("name", Json::from(self.name.as_str())),
            ("iters", Json::from(self.iters)),
            ("min_ns", Json::from(self.min.as_secs_f64() * 1e9)),
            ("mean_ns", Json::from(self.mean.as_secs_f64() * 1e9)),
            ("p50_ns", Json::from(self.p50.as_secs_f64() * 1e9)),
        ];
        if let Some(t) = self.throughput {
            kv.push(("throughput_per_s", Json::from(t)));
        }
        Json::obj(kv)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations then `iters` measured.
/// In quick mode ([`quick_mode`]) warmup is capped at 1 and iters at 3 so
/// the CI bench-regression job stays smoke-sized (3 samples keep the
/// min-based regression gate reasonably noise-robust).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    let (warmup, iters) =
        if quick_mode() { (warmup.min(1), iters.min(3)) } else { (warmup, iters) };
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let min = samples[0];
    let p50 = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult { name: name.to_string(), iters, min, mean, p50, throughput: None }
}

/// Time a single invocation (for end-to-end figure generators where one run
/// is already seconds).
pub fn time_once<R, F: FnOnce() -> R>(name: &str, f: F) -> (R, BenchResult) {
    let t0 = Instant::now();
    let r = f();
    let d = t0.elapsed();
    let b = BenchResult {
        name: name.to_string(),
        iters: 1,
        min: d,
        mean: d,
        p50: d,
        throughput: None,
    };
    (r, b)
}

/// Collector that prints results as they land and can dump a summary.
#[derive(Default)]
pub struct Runner {
    pub results: Vec<BenchResult>,
}

impl Runner {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        let r = bench(name, warmup, iters, f);
        println!("{}", r.line());
        self.results.push(r);
    }

    /// `run` plus an items/s throughput column (e.g. explorer points/s).
    pub fn run_with_items<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        items_per_iter: f64,
        f: F,
    ) {
        let r = bench(name, warmup, iters, f).with_throughput(items_per_iter);
        println!("{}", r.line());
        self.results.push(r);
    }

    pub fn run_once<R, F: FnOnce() -> R>(&mut self, name: &str, f: F) -> R {
        let (out, r) = time_once(name, f);
        println!("{}", r.line());
        self.results.push(r);
        out
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for r in &self.results {
            s.push_str(&r.line());
            s.push('\n');
        }
        s
    }

    /// Machine-readable results keyed by bench-target name — one object the
    /// CI job merges across targets into `BENCH_*.json`.
    pub fn to_json(&self, bench_name: &str) -> Json {
        Json::Obj(vec![(
            bench_name.to_string(),
            Json::obj(vec![(
                "results",
                Json::arr(self.results.iter().map(BenchResult::to_json)),
            )]),
        )])
    }

    /// Write `results/bench_<name>.json` for the CI bench-regression gate.
    pub fn write_json(&self, bench_name: &str) -> std::io::Result<std::path::PathBuf> {
        crate::util::table::write_result(
            &format!("bench_{bench_name}.json"),
            &self.to_json(bench_name).pretty(),
        )
    }
}

/// One >tolerance move between a current and baseline bench entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    pub bench: String,
    pub name: String,
    /// `min_ns` (grew) or `throughput_per_s` (shrank).
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// Slowdown factor (> 1).
    pub ratio: f64,
}

/// Outcome of one baseline comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineComparison {
    /// Entries present in both the current results and the baseline.
    pub compared: usize,
    pub regressions: Vec<Regression>,
}

/// Compare merged bench JSON (the [`Runner::to_json`] shape, one key per
/// bench target) against a committed baseline. Entries missing from the
/// baseline are skipped — the bootstrap path: CI uploads the merged JSON as
/// an artifact so maintainers can copy it into the baseline to arm the
/// gate. A regression is a min time that grew, or a throughput that
/// shrank, by more than `tolerance` (0.3 = 30%); min is compared instead
/// of p50 because CI-runner noise is one-sided.
pub fn compare_to_baseline(current: &Json, baseline: &Json, tolerance: f64) -> BaselineComparison {
    let mut cmp = BaselineComparison { compared: 0, regressions: Vec::new() };
    let Json::Obj(benches) = current else {
        return cmp;
    };
    for (bench, cur) in benches {
        let Some(base) = baseline.get(bench) else {
            continue;
        };
        let cur_rs = cur.get("results").and_then(Json::as_array).unwrap_or(&[]);
        let base_rs = base.get("results").and_then(Json::as_array).unwrap_or(&[]);
        for c in cur_rs {
            let Some(name) = c.get("name").and_then(Json::as_str) else {
                continue;
            };
            let Some(b) = base_rs.iter().find(|r| r.get("name").and_then(Json::as_str) == Some(name))
            else {
                continue;
            };
            cmp.compared += 1;
            if let (Some(cp), Some(bp)) = (
                c.get("min_ns").and_then(Json::as_f64),
                b.get("min_ns").and_then(Json::as_f64),
            ) {
                if bp > 0.0 && cp > bp * (1.0 + tolerance) {
                    cmp.regressions.push(Regression {
                        bench: bench.clone(),
                        name: name.to_string(),
                        metric: "min_ns",
                        baseline: bp,
                        current: cp,
                        ratio: cp / bp,
                    });
                }
            }
            if let (Some(ct), Some(bt)) = (
                c.get("throughput_per_s").and_then(Json::as_f64),
                b.get("throughput_per_s").and_then(Json::as_f64),
            ) {
                if bt > 0.0 && ct > 0.0 && ct < bt / (1.0 + tolerance) {
                    cmp.regressions.push(Regression {
                        bench: bench.clone(),
                        name: name.to_string(),
                        metric: "throughput_per_s",
                        baseline: bt,
                        current: ct,
                        ratio: bt / ct,
                    });
                }
            }
        }
    }
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0usize;
        let r = bench("inc", 2, 5, || n += 1);
        if quick_mode() {
            assert!(r.iters <= 3);
        } else {
            assert_eq!(n, 7); // 2 warmup + 5 measured
            assert_eq!(r.iters, 5);
        }
        assert!(r.min <= r.p50);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, r) = time_once("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn runner_accumulates() {
        let mut run = Runner::new();
        run.run("a", 0, 1, || {});
        let out = run.run_once("b", || 7);
        assert_eq!(out, 7);
        assert_eq!(run.results.len(), 2);
        assert!(run.summary().contains("a"));
    }

    #[test]
    fn throughput_and_json_shape() {
        let mut run = Runner::new();
        run.run_with_items("t", 0, 1, 100.0, || std::thread::sleep(Duration::from_millis(1)));
        let r = run.results.last().unwrap();
        let t = r.throughput.expect("throughput set");
        assert!(t > 0.0 && t < 1e6, "100 items over >=1ms: {t}");
        let j = run.to_json("demo");
        let results = j.get("demo").unwrap().get("results").unwrap();
        let e = &results.as_array().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("t"));
        assert!(e.get("p50_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(e.get("throughput_per_s").is_some());
    }

    fn entry(name: &str, min_ns: f64, thr: Option<f64>) -> Json {
        let mut kv = vec![("name", Json::from(name)), ("min_ns", Json::from(min_ns))];
        if let Some(t) = thr {
            kv.push(("throughput_per_s", Json::from(t)));
        }
        Json::obj(kv)
    }

    fn bench_json(bench: &str, entries: Vec<Json>) -> Json {
        Json::Obj(vec![(
            bench.to_string(),
            Json::obj(vec![("results", Json::Arr(entries))]),
        )])
    }

    #[test]
    fn baseline_comparison_flags_only_regressions() {
        let baseline = bench_json(
            "explore",
            vec![entry("a", 100.0, Some(50.0)), entry("b", 100.0, None)],
        );
        // a: min fine but throughput collapsed; b: min 2x slower
        let current = bench_json(
            "explore",
            vec![entry("a", 110.0, Some(10.0)), entry("b", 200.0, None)],
        );
        let cmp = compare_to_baseline(&current, &baseline, 0.3);
        assert_eq!(cmp.compared, 2);
        assert_eq!(cmp.regressions.len(), 2);
        assert_eq!(cmp.regressions[0].metric, "throughput_per_s");
        assert_eq!(cmp.regressions[1].metric, "min_ns");
        // improvements and in-tolerance noise never flag
        let ok = bench_json(
            "explore",
            vec![entry("a", 90.0, Some(60.0)), entry("b", 125.0, None)],
        );
        assert!(compare_to_baseline(&ok, &baseline, 0.3).regressions.is_empty());
    }

    #[test]
    fn quick_mode_env_policy_is_pure() {
        assert!(quick_from_env(Some("1")));
        assert!(quick_from_env(Some("true")));
        assert!(!quick_from_env(Some("0")));
        assert!(!quick_from_env(Some("yes")));
        assert!(!quick_from_env(None));
        // the cached reader agrees with the policy for the ambient env
        assert_eq!(
            quick_mode(),
            quick_from_env(std::env::var("DFMODEL_BENCH_QUICK").ok().as_deref())
        );
    }

    #[test]
    fn missing_baseline_entries_are_skipped() {
        let current = bench_json("explore", vec![entry("new", 100.0, None)]);
        let cmp = compare_to_baseline(&current, &Json::obj(vec![]), 0.3);
        assert_eq!(cmp.compared, 0);
        assert!(cmp.regressions.is_empty());
        // a baseline for a different bench target is also skipped
        let other = bench_json("cluster_sim", vec![entry("new", 1.0, None)]);
        assert_eq!(compare_to_baseline(&current, &other, 0.3).compared, 0);
    }
}
