//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock of closures with warmup, reports min/mean/p50, and is
//! the engine behind `cargo bench` (the `[[bench]]` targets set
//! `harness = false` and call into this module).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<48} iters={:<4} min={:>12?} mean={:>12?} p50={:>12?}",
            self.name, self.iters, self.min, self.mean, self.p50
        )
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations then `iters` measured.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let min = samples[0];
    let p50 = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult { name: name.to_string(), iters, min, mean, p50 }
}

/// Time a single invocation (for end-to-end figure generators where one run
/// is already seconds).
pub fn time_once<R, F: FnOnce() -> R>(name: &str, f: F) -> (R, BenchResult) {
    let t0 = Instant::now();
    let r = f();
    let d = t0.elapsed();
    (r, BenchResult { name: name.to_string(), iters: 1, min: d, mean: d, p50: d })
}

/// Collector that prints results as they land and can dump a summary.
#[derive(Default)]
pub struct Runner {
    pub results: Vec<BenchResult>,
}

impl Runner {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        let r = bench(name, warmup, iters, f);
        println!("{}", r.line());
        self.results.push(r);
    }

    pub fn run_once<R, F: FnOnce() -> R>(&mut self, name: &str, f: F) -> R {
        let (out, r) = time_once(name, f);
        println!("{}", r.line());
        self.results.push(r);
        out
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for r in &self.results {
            s.push_str(&r.line());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0usize;
        let r = bench("inc", 2, 5, || n += 1);
        assert_eq!(n, 7); // 2 warmup + 5 measured
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.p50);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, r) = time_once("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn runner_accumulates() {
        let mut run = Runner::new();
        run.run("a", 0, 1, || {});
        let out = run.run_once("b", || 7);
        assert_eq!(out, 7);
        assert_eq!(run.results.len(), 2);
        assert!(run.summary().contains("a"));
    }
}
