//! ASCII table / heatmap / CSV rendering for the figure generators.
//!
//! The paper's evaluation is heatmaps (Figs 10/12/14/16), stacked latency
//! breakdowns (Figs 11/13/15/17), sweeps (Figs 7/8/19–22) and tables
//! (Tables V/VI). Every bench renders through this module so results are
//! both human-readable (stdout) and machine-readable (CSV in results/).

use std::fmt::Write as _;

/// Column-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                let _ = write!(s, " {}{} |", c, " ".repeat(pad));
            }
            out.push_str(&s);
            out.push('\n');
        };
        let sep: String = {
            let mut s = String::from("+");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        out.push_str(&sep);
        line(&mut out, &self.headers);
        out.push_str(&sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out.push_str(&sep);
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// 2-D heatmap with row/col labels, rendered with a unicode shade ramp plus
/// the numeric value (the paper's Figs 10/12/14/16 are value-annotated
/// heatmaps).
#[derive(Debug, Clone)]
pub struct Heatmap {
    pub title: String,
    pub row_labels: Vec<String>,
    pub col_labels: Vec<String>,
    pub values: Vec<Vec<f64>>,
}

const RAMP: [char; 5] = [' ', '░', '▒', '▓', '█'];

impl Heatmap {
    pub fn new(title: &str, rows: &[&str], cols: &[&str]) -> Self {
        Heatmap {
            title: title.to_string(),
            row_labels: rows.iter().map(|s| s.to_string()).collect(),
            col_labels: cols.iter().map(|s| s.to_string()).collect(),
            values: vec![vec![f64::NAN; cols.len()]; rows.len()],
        }
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.values[r][c] = v;
    }

    pub fn render(&self) -> String {
        let finite: Vec<f64> =
            self.values.iter().flatten().copied().filter(|v| v.is_finite()).collect();
        let (lo, hi) = finite
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        let shade = |v: f64| -> char {
            if !v.is_finite() || hi <= lo {
                RAMP[0]
            } else {
                let t = (v - lo) / (hi - lo);
                RAMP[((t * (RAMP.len() - 1) as f64).round() as usize).min(RAMP.len() - 1)]
            }
        };
        let rw = self.row_labels.iter().map(|s| s.chars().count()).max().unwrap_or(0);
        let cw = self
            .col_labels
            .iter()
            .map(|s| s.chars().count())
            .max()
            .unwrap_or(0)
            .max(7);
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let _ = write!(out, "{} ", " ".repeat(rw));
        for c in &self.col_labels {
            let _ = write!(out, "{c:>cw$} ");
        }
        out.push('\n');
        for (r, label) in self.row_labels.iter().enumerate() {
            let _ = write!(out, "{label:>rw$} ");
            for v in &self.values[r] {
                let cell = if v.is_finite() {
                    format!("{}{:.3}", shade(*v), v)
                } else {
                    "-".to_string()
                };
                let _ = write!(out, "{cell:>cw$} ");
            }
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            "",
            &std::iter::once("row")
                .chain(self.col_labels.iter().map(|s| s.as_str()))
                .collect::<Vec<_>>(),
        );
        for (r, label) in self.row_labels.iter().enumerate() {
            let mut cells = vec![label.clone()];
            cells.extend(self.values[r].iter().map(|v| format!("{v}")));
            t.row(&cells);
        }
        t.to_csv()
    }
}

/// Horizontal stacked-bar chart (latency breakdowns, Figs 11/13/15/17).
pub fn stacked_bars(
    title: &str,
    labels: &[String],
    series_names: &[&str],
    series: &[Vec<f64>], // series[s][i]
    width: usize,
) -> String {
    assert_eq!(series.len(), series_names.len());
    let glyphs = ['#', '=', '.', '+', '~'];
    let totals: Vec<f64> =
        (0..labels.len()).map(|i| series.iter().map(|s| s[i]).sum()).collect();
    let max = totals.iter().cloned().fold(0.0f64, f64::max).max(1e-30);
    let lw = labels.iter().map(|s| s.chars().count()).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let legend: Vec<String> = series_names
        .iter()
        .enumerate()
        .map(|(s, n)| format!("{} {}", glyphs[s % glyphs.len()], n))
        .collect();
    let _ = writeln!(out, "legend: {}", legend.join("  "));
    for (i, label) in labels.iter().enumerate() {
        let mut bar = String::new();
        for (s, vals) in series.iter().enumerate() {
            let n = ((vals[i] / max) * width as f64).round() as usize;
            bar.push_str(&glyphs[s % glyphs.len()].to_string().repeat(n));
        }
        let _ = writeln!(out, "{label:>lw$} |{bar} ({:.4})", totals[i]);
    }
    out
}

/// Write a string to `results/<name>`, creating the directory.
pub fn write_result(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| long-name | 2.5 |"));
        assert!(s.contains("| a         | 1   |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["hello, world".into()]);
        assert!(t.to_csv().contains("\"hello, world\""));
    }

    #[test]
    fn heatmap_renders_and_shades() {
        let mut h = Heatmap::new("hm", &["r1", "r2"], &["c1", "c2"]);
        h.set(0, 0, 0.0);
        h.set(0, 1, 1.0);
        h.set(1, 0, 0.5);
        h.set(1, 1, 0.25);
        let s = h.render();
        assert!(s.contains("== hm =="));
        assert!(s.contains('█')); // max cell gets full shade
        let csv = h.to_csv();
        assert!(csv.starts_with("row,c1,c2"));
    }

    #[test]
    fn heatmap_handles_nan() {
        let h = Heatmap::new("hm", &["r"], &["c"]);
        let s = h.render();
        assert!(s.contains('-'));
    }

    #[test]
    fn stacked_bars_render() {
        let s = stacked_bars(
            "break",
            &["cfg1".into(), "cfg2".into()],
            &["comp", "mem"],
            &[vec![1.0, 2.0], vec![0.5, 0.0]],
            20,
        );
        assert!(s.contains("legend"));
        assert!(s.contains("cfg1"));
        assert!(s.contains('#'));
    }
}
