//! Property-check harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a randomized property many times
//! with deterministic per-case seeds; on failure it reports the seed so the
//! case can be replayed exactly (`CHECK_SEED=<n>`).

use crate::util::prng::Rng;

/// Run `prop` for `cases` deterministic seeds. The property should panic
/// (e.g. via assert!) on violation; the harness wraps the panic with the
/// failing seed for replay.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    // Replay mode: CHECK_SEED pins a single failing case.
    if let Ok(seed) = std::env::var("CHECK_SEED") {
        let seed: u64 = seed.parse().expect("CHECK_SEED must be u64");
        let mut rng = Rng::new(seed);
        prop(&mut rng);
        return;
    }
    // Probing runs expected failures under catch_unwind: silence the
    // default panic hook while probing so they don't spray backtraces into
    // test output, and restore it before reporting (so the harness's own
    // failure panic still prints normally).
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut failure: Option<(u64, u64, String)> = None;
    for case in 0..cases {
        let seed = splitmix(name, case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            failure = Some((case, seed, msg));
            break;
        }
    }
    std::panic::set_hook(hook);
    if let Some((case, seed, msg)) = failure {
        panic!("property '{name}' failed at case {case} (CHECK_SEED={seed}): {msg}");
    }
}

fn splitmix(name: &str, case: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(0x100000001b3);
    }
    h ^ case.wrapping_mul(0x9E3779B97F4A7C15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("always-true", 50, |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-false", 5, |_rng| {
                assert!(false, "boom");
            });
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("CHECK_SEED="), "{msg}");
    }

    #[test]
    fn seeds_are_name_dependent() {
        assert_ne!(splitmix("a", 0), splitmix("b", 0));
        assert_ne!(splitmix("a", 0), splitmix("a", 1));
    }
}
