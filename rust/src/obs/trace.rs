//! Capture assembly and exporters: the span tree, the metrics JSON section,
//! and Chrome trace-event JSON for Perfetto / `chrome://tracing`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::metrics::{Hist, Metric};
use super::Ev;
use crate::util::json::Json;
use crate::util::units::fmt_time;

/// One completed span: name, wall-clock interval, logical track, children.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanNode {
    pub name: String,
    /// Logical track: 0 = the capturing thread, 1.. = spliced worker items
    /// numbered in splice order (deterministic; never an OS thread id).
    pub track: u32,
    pub start_us: u64,
    pub end_us: u64,
    pub children: Vec<SpanNode>,
}

impl SpanNode {
    /// Span duration in seconds.
    pub fn secs(&self) -> f64 {
        (self.end_us.saturating_sub(self.start_us)) as f64 * 1e-6
    }
}

/// Everything one capture recorded: root spans plus aggregated metrics.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Capture {
    pub roots: Vec<SpanNode>,
    /// Aggregated metrics, sorted by name.
    pub metrics: Vec<(String, Metric)>,
}

/// Assemble raw events into a span tree + aggregated metrics. Unmatched
/// Ends are dropped and still-open spans are closed at their start time, so
/// a torn capture degrades instead of panicking.
pub(crate) fn build(events: Vec<Ev>) -> Capture {
    let mut roots: Vec<SpanNode> = Vec::new();
    let mut stack: Vec<SpanNode> = Vec::new();
    let mut metrics: BTreeMap<String, Metric> = BTreeMap::new();
    let mut track = 0u32;
    let mut next_track = 1u32;
    let mut track_stack: Vec<u32> = Vec::new();
    for ev in events {
        match ev {
            Ev::Begin { name, t_us } => stack.push(SpanNode {
                name,
                track,
                start_us: t_us,
                end_us: t_us,
                children: Vec::new(),
            }),
            Ev::End { t_us } => {
                if let Some(mut n) = stack.pop() {
                    n.end_us = t_us;
                    attach(&mut roots, &mut stack, n);
                }
            }
            Ev::TaskOpen => {
                track_stack.push(track);
                track = next_track;
                next_track += 1;
            }
            Ev::TaskClose => track = track_stack.pop().unwrap_or(0),
            Ev::Count { name, delta } => {
                if let Metric::Counter(c) = metrics.entry(name).or_insert(Metric::Counter(0)) {
                    *c += delta;
                }
            }
            Ev::Gauge { name, v } => {
                if let Metric::Gauge(g) = metrics.entry(name).or_insert(Metric::Gauge(v)) {
                    *g = v;
                }
            }
            Ev::Observe { name, v } => {
                if let Metric::Histogram(h) =
                    metrics.entry(name).or_insert_with(|| Metric::Histogram(Hist::new()))
                {
                    h.add(v);
                }
            }
        }
    }
    while let Some(n) = stack.pop() {
        attach(&mut roots, &mut stack, n);
    }
    Capture { roots, metrics: metrics.into_iter().collect() }
}

fn attach(roots: &mut Vec<SpanNode>, stack: &mut [SpanNode], n: SpanNode) {
    if let Some(parent) = stack.last_mut() {
        parent.children.push(n);
    } else {
        roots.push(n);
    }
}

/// Lines the human span tree prints before truncating (a traced explore can
/// record one subtree per candidate).
const TREE_LIMIT: usize = 48;

impl Capture {
    /// Counter value by name; `None` when absent or not a counter.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.metrics.iter().find(|(n, _)| n == name).and_then(|(_, m)| match m {
            Metric::Counter(c) => Some(*c),
            _ => None,
        })
    }

    /// Total number of spans in the tree.
    pub fn n_spans(&self) -> usize {
        fn count(n: &SpanNode) -> usize {
            1 + n.children.iter().map(count).sum::<usize>()
        }
        self.roots.iter().map(count).sum()
    }

    /// Names-and-nesting-only rendering (no timings, no tracks): the
    /// deterministic shape compared by worker-count-independence tests.
    pub fn structure(&self) -> String {
        fn walk(s: &mut String, n: &SpanNode, depth: usize) {
            let _ = writeln!(s, "{}{}", "  ".repeat(depth), n.name);
            for c in &n.children {
                walk(s, c, depth + 1);
            }
        }
        let mut s = String::new();
        for r in &self.roots {
            walk(&mut s, r, 0);
        }
        s
    }

    /// Human-readable span tree with durations (the `Report::render`
    /// footer). Truncates after [`TREE_LIMIT`] lines.
    pub fn span_tree(&self) -> String {
        fn walk(s: &mut String, n: &SpanNode, depth: usize, left: &mut usize) {
            if *left == 0 {
                return;
            }
            *left -= 1;
            let label = format!("{}{}", "  ".repeat(depth), n.name);
            let _ = writeln!(s, "  {label:<42} {}", fmt_time(n.secs()));
            for c in &n.children {
                walk(s, c, depth + 1, left);
            }
        }
        if self.roots.is_empty() {
            return String::new();
        }
        let mut s = String::from("spans    :\n");
        let mut left = TREE_LIMIT;
        for r in &self.roots {
            walk(&mut s, r, 0, &mut left);
        }
        let total = self.n_spans();
        if total > TREE_LIMIT {
            let _ = writeln!(s, "  ... ({} more spans)", total - TREE_LIMIT);
        }
        s
    }

    /// Metrics as text lines (appended to the report footer).
    pub fn metrics_text(&self) -> String {
        if self.metrics.is_empty() {
            return String::new();
        }
        let mut s = String::new();
        let _ = writeln!(s, "stats    : {} metric(s)", self.metrics.len());
        for (name, m) in &self.metrics {
            let _ = match m {
                Metric::Counter(c) => writeln!(s, "  {name} = {c}"),
                Metric::Gauge(v) => writeln!(s, "  {name} = {v:.6}"),
                Metric::Histogram(h) => writeln!(
                    s,
                    "  {name}: n={} mean={:.4e} min={:.4e} max={:.4e}",
                    h.count,
                    h.mean(),
                    h.min,
                    h.max
                ),
            };
        }
        s
    }

    /// Metrics as a JSON object — the `Report.stats` section.
    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.metrics
                .iter()
                .map(|(name, m)| {
                    let v = match m {
                        Metric::Counter(c) => Json::obj(vec![
                            ("kind", Json::from("counter")),
                            ("value", Json::from(*c as f64)),
                        ]),
                        Metric::Gauge(g) => Json::obj(vec![
                            ("kind", Json::from("gauge")),
                            ("value", Json::from(*g)),
                        ]),
                        Metric::Histogram(h) => Json::obj(vec![
                            ("kind", Json::from("histogram")),
                            ("count", Json::from(h.count as f64)),
                            ("sum", Json::from(h.sum)),
                            ("min", Json::from(h.min)),
                            ("max", Json::from(h.max)),
                            (
                                "buckets",
                                Json::arr(h.buckets.iter().map(|&(ub, c)| {
                                    Json::arr([Json::from(ub), Json::from(c as f64)])
                                })),
                            ),
                        ]),
                    };
                    (name.clone(), v)
                })
                .collect(),
        )
    }
}

/// Chrome trace-event JSON: an array of matched `"B"`/`"E"` duration events
/// (one process, one `tid` per logical track), loadable in Perfetto or
/// `chrome://tracing`.
pub fn chrome_trace(c: &Capture) -> Json {
    fn emit(out: &mut Vec<Json>, n: &SpanNode) {
        let tid = Json::from(f64::from(n.track) + 1.0);
        out.push(Json::obj(vec![
            ("name", Json::from(n.name.as_str())),
            ("cat", Json::from("dfmodel")),
            ("ph", Json::from("B")),
            ("ts", Json::from(n.start_us as f64)),
            ("pid", Json::from(1.0)),
            ("tid", tid.clone()),
        ]));
        for ch in &n.children {
            emit(out, ch);
        }
        out.push(Json::obj(vec![
            ("name", Json::from(n.name.as_str())),
            ("ph", Json::from("E")),
            ("ts", Json::from(n.end_us as f64)),
            ("pid", Json::from(1.0)),
            ("tid", tid),
        ]));
    }
    let mut out = Vec::new();
    for r in &c.roots {
        emit(&mut out, r);
    }
    Json::Arr(out)
}

#[cfg(test)]
mod tests {
    use crate::obs;
    use crate::util::json::Json;

    fn phase_count(trace: &Json, ph: &str) -> usize {
        trace
            .as_array()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph))
            .count()
    }

    #[test]
    fn capture_builds_a_nested_tree_and_aggregates_metrics() {
        let sess = obs::start_capture();
        {
            let _a = obs::span("outer");
            {
                let _b = obs::span("inner");
            }
            obs::counter("n", 2);
            obs::counter("n", 3);
            obs::gauge("g", 1.5);
            obs::observe("h", 0.25);
            obs::observe("h", 4.0);
        }
        let cap = obs::finish_capture(sess);
        assert_eq!(cap.roots.len(), 1);
        assert_eq!(cap.roots[0].name, "outer");
        assert_eq!(cap.roots[0].children.len(), 1);
        assert_eq!(cap.roots[0].children[0].name, "inner");
        assert_eq!(cap.counter("n"), Some(5));
        assert_eq!(cap.n_spans(), 2);
        match cap.metrics.iter().find(|(n, _)| n == "h").map(|(_, m)| m) {
            Some(obs::Metric::Histogram(h)) => assert_eq!(h.count, 2),
            other => panic!("expected histogram, got {other:?}"),
        }
        let tr = obs::chrome_trace(&cap);
        assert_eq!(phase_count(&tr, "B"), 2);
        assert_eq!(phase_count(&tr, "E"), 2);
    }

    #[test]
    fn chrome_trace_escapes_names_and_round_trips_as_json() {
        let sess = obs::start_capture();
        {
            let _s = obs::span("kernel \"fused\"\nmatmul\t[0]");
        }
        let cap = obs::finish_capture(sess);
        let text = obs::chrome_trace(&cap).pretty();
        let parsed = Json::parse(&text).expect("exported trace must be valid JSON");
        let name = parsed.as_array().unwrap()[0].get("name").unwrap().as_str().unwrap();
        assert_eq!(name, "kernel \"fused\"\nmatmul\t[0]");
    }

    #[test]
    fn probes_without_an_armed_capture_record_nothing() {
        {
            let _orphan = obs::span("dropped");
            obs::counter("dropped", 1);
        }
        let sess = obs::start_capture();
        let cap = obs::finish_capture(sess);
        assert!(cap.roots.is_empty());
        assert!(cap.metrics.is_empty());
    }

    #[test]
    fn spliced_tasks_keep_item_order_and_get_distinct_tracks() {
        let sess = obs::start_capture();
        {
            let _p = obs::span("parent");
            let logs: Vec<obs::TaskLog> = (0..3)
                .map(|i| {
                    let ((), log) = obs::record_task(|| {
                        let _s = obs::span(&format!("item{i}"));
                        obs::counter("items", 1);
                    });
                    log
                })
                .collect();
            obs::splice_tasks(logs);
        }
        let cap = obs::finish_capture(sess);
        let kids: Vec<&str> = cap.roots[0].children.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(kids, ["item0", "item1", "item2"]);
        assert_eq!(cap.roots[0].track, 0);
        let tracks: Vec<u32> = cap.roots[0].children.iter().map(|c| c.track).collect();
        assert_eq!(tracks, [1, 2, 3]);
        assert_eq!(cap.counter("items"), Some(3));
    }

    #[test]
    fn a_dropped_session_disarms_recording() {
        let sess = obs::start_capture();
        drop(sess);
        {
            let _s = obs::span("after-drop");
        }
        let sess = obs::start_capture();
        let cap = obs::finish_capture(sess);
        assert!(cap.roots.is_empty());
    }
}
