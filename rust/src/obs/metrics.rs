//! Aggregated metric values: counters, gauges, and log-scale histograms
//! with fixed power-of-two buckets.

/// One aggregated metric. The first event recorded under a name decides its
/// kind; later events of a different kind for the same name are ignored.
#[derive(Debug, Clone, PartialEq)]
pub enum Metric {
    /// Monotonic event count.
    Counter(u64),
    /// Last-write-wins instantaneous value.
    Gauge(f64),
    /// Log-scale sample distribution.
    Histogram(Hist),
}

/// Number of histogram buckets.
const N_BUCKETS: usize = 64;

/// Upper bound of bucket `i`: `2^(i - 32)`, exact in f64. The 64 buckets
/// cover ~2.3e-10 .. 2.1e9 — enough for byte counts, durations in seconds,
/// queue depths, and utilization fractions alike.
pub fn bucket_upper_bound(i: usize) -> f64 {
    (i as f64 - 32.0).exp2()
}

/// First bucket whose upper bound is `>= v`; out-of-range samples clamp to
/// the edge buckets. A short linear scan keeps the mapping bit-identical on
/// every platform (no libm `log2` involved).
fn bucket_index(v: f64) -> usize {
    let mut i = 0;
    while i < N_BUCKETS - 1 && bucket_upper_bound(i) < v {
        i += 1;
    }
    i
}

/// Histogram over fixed log-scale buckets (see [`bucket_upper_bound`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// Occupied buckets only, ascending: `(upper_bound, count)`.
    pub buckets: Vec<(f64, u64)>,
}

impl Hist {
    pub(crate) fn new() -> Hist {
        Hist {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: Vec::new(),
        }
    }

    pub(crate) fn add(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let ub = bucket_upper_bound(bucket_index(v));
        match self.buckets.binary_search_by(|b| b.0.total_cmp(&ub)) {
            Ok(k) => self.buckets[k].1 += 1,
            Err(k) => self.buckets.insert(k, (ub, 1)),
        }
    }

    /// Arithmetic mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_monotonic_powers_of_two() {
        for i in 1..N_BUCKETS {
            assert_eq!(bucket_upper_bound(i), 2.0 * bucket_upper_bound(i - 1));
        }
        assert_eq!(bucket_upper_bound(32), 1.0);
    }

    #[test]
    fn samples_land_in_the_first_covering_bucket_and_clamp_at_edges() {
        assert_eq!(bucket_index(1.0), 32);
        assert_eq!(bucket_index(1.5), 33);
        assert_eq!(bucket_index(0.5), 31);
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(f64::INFINITY), N_BUCKETS - 1);
        assert_eq!(bucket_index(1e300), N_BUCKETS - 1);
    }

    #[test]
    fn histogram_tracks_count_sum_extremes_and_occupied_buckets() {
        let mut h = Hist::new();
        for v in [0.25, 0.25, 1.0, 100.0] {
            h.add(v);
        }
        assert_eq!(h.count, 4);
        assert!((h.sum - 101.5).abs() < 1e-12);
        assert_eq!(h.min, 0.25);
        assert_eq!(h.max, 100.0);
        assert!((h.mean() - 25.375).abs() < 1e-12);
        // 0.25 twice -> one bucket with count 2; three occupied buckets total
        assert_eq!(h.buckets.len(), 3);
        assert_eq!(h.buckets[0], (0.25, 2));
        // buckets stay sorted by upper bound
        assert!(h.buckets.windows(2).all(|w| w[0].0 < w[1].0));
    }
}
