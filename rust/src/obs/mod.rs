//! In-tree, zero-dependency observability: hierarchical spans plus a
//! metrics registry, captured per evaluation and exported as Chrome
//! trace-event JSON (Perfetto / `chrome://tracing`), a human-readable span
//! tree (the `Report::render` footer), and a metrics section on `Report`
//! (`Report.stats`). See DESIGN.md §Observability for the naming scheme.
//!
//! Design:
//! - Recording is **off by default**. Every instrumentation probe starts
//!   with one relaxed atomic load and returns immediately when no capture
//!   is armed anywhere in the process — the overhead contract benchmarked
//!   by `benches/obs.rs` and gated by `dfmodel bench-check`.
//! - A capture is **thread-scoped**: [`start_capture`] arms the calling
//!   thread's log, and spans/metrics recorded on other threads are dropped
//!   unless they run inside [`record_task`] — the hook `util::threadpool`
//!   uses to buffer each work item's events and splice them back in
//!   deterministic item order via [`splice_tasks`]. Two concurrent captures
//!   on different threads therefore never contaminate each other (cargo's
//!   parallel test runner relies on this).
//! - Span ids and tree shape come from the merged event order, not from OS
//!   scheduling: worker items are spliced in item-index order, so the same
//!   scenario yields the same span tree regardless of worker count.

mod metrics;
mod trace;

pub use metrics::{bucket_upper_bound, Hist, Metric};
pub use trace::{chrome_trace, Capture, SpanNode};

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::units::{Bytes, Seconds};

/// Number of currently armed captures across all threads. Zero keeps every
/// probe on the single-atomic-load fast path.
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

/// Monotonic time base shared by every thread (first use wins).
static CLOCK: OnceLock<Instant> = OnceLock::new();

fn now_us() -> u64 {
    CLOCK.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// True when at least one capture is armed anywhere in the process. Cheap
/// enough for per-event call sites; hot loops may hoist it.
#[inline]
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed) != 0
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// Not recording on this thread.
    Off,
    /// This thread owns an armed capture.
    Capture,
    /// Inside [`record_task`]: events go to a detached per-item buffer.
    Task,
}

/// One raw recorded event; assembled into a [`Capture`] at finish time.
pub(crate) enum Ev {
    Begin { name: String, t_us: u64 },
    End { t_us: u64 },
    Count { name: String, delta: u64 },
    Gauge { name: String, v: f64 },
    Observe { name: String, v: f64 },
    /// Markers bracketing one spliced worker item (each open assigns the
    /// next logical track id).
    TaskOpen,
    TaskClose,
}

struct ThreadLog {
    mode: Mode,
    events: Vec<Ev>,
}

thread_local! {
    static LOG: RefCell<ThreadLog> =
        const { RefCell::new(ThreadLog { mode: Mode::Off, events: Vec::new() }) };
}

/// Push `ev` if this thread is recording; reports whether it was kept.
fn try_record(ev: Ev) -> bool {
    LOG.with(|l| {
        let mut l = l.borrow_mut();
        if l.mode == Mode::Off {
            return false;
        }
        l.events.push(ev);
        true
    })
}

/// RAII span guard: records a Begin on creation and the matching End when
/// dropped. Free when no capture is armed on this thread.
#[must_use = "a span lasts until the guard drops; an unbound guard ends immediately"]
pub struct SpanGuard {
    armed: bool,
}

/// Open a hierarchical span named `name` on the current thread.
pub fn span(name: &str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { armed: false };
    }
    let armed = try_record(Ev::Begin { name: name.to_string(), t_us: now_us() });
    SpanGuard { armed }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            // if the capture was disarmed mid-span the End lands in a dead
            // buffer and is discarded with it
            try_record(Ev::End { t_us: now_us() });
        }
    }
}

/// Add `delta` to the named counter.
pub fn counter(name: &str, delta: u64) {
    if enabled() {
        try_record(Ev::Count { name: name.to_string(), delta });
    }
}

/// Set the named gauge to its latest value.
pub fn gauge(name: &str, v: f64) {
    if enabled() {
        try_record(Ev::Gauge { name: name.to_string(), v });
    }
}

/// Record one sample into the named log-scale histogram.
pub fn observe(name: &str, v: f64) {
    if enabled() {
        try_record(Ev::Observe { name: name.to_string(), v });
    }
}

/// [`observe`] for [`Seconds`] quantities; name the metric `*_seconds`.
pub fn observe_seconds(name: &str, s: Seconds) {
    observe(name, s.raw());
}

/// [`observe`] for [`Bytes`] quantities; name the metric `*_bytes`.
pub fn observe_bytes(name: &str, b: Bytes) {
    observe(name, b.raw());
}

/// An armed capture on the current thread (from [`start_capture`]).
/// Dropping it without [`finish_capture`] disarms and discards the events.
/// `!Send` on purpose: a capture must finish on the thread that armed it.
pub struct CaptureSession {
    done: bool,
    _pin: std::marker::PhantomData<*const ()>,
}

/// Arm a capture on the calling thread. Events recorded on this thread —
/// and in worker items spliced back via [`record_task`]/[`splice_tasks`] —
/// accumulate until [`finish_capture`]. One capture per thread at a time.
pub fn start_capture() -> CaptureSession {
    LOG.with(|l| {
        let mut l = l.borrow_mut();
        l.mode = Mode::Capture;
        l.events.clear();
    });
    ACTIVE.fetch_add(1, Ordering::Relaxed);
    CaptureSession { done: false, _pin: std::marker::PhantomData }
}

/// Disarm the capture and assemble its events into a [`Capture`].
pub fn finish_capture(mut session: CaptureSession) -> Capture {
    session.done = true;
    trace::build(disarm())
}

fn disarm() -> Vec<Ev> {
    let events = LOG.with(|l| {
        let mut l = l.borrow_mut();
        l.mode = Mode::Off;
        std::mem::take(&mut l.events)
    });
    ACTIVE.fetch_sub(1, Ordering::Relaxed);
    events
}

impl Drop for CaptureSession {
    fn drop(&mut self) {
        if !self.done {
            drop(disarm());
        }
    }
}

/// Events recorded by one worker item, detached from any thread
/// (see `util::threadpool::parallel_map_workers`).
pub struct TaskLog {
    events: Vec<Ev>,
}

impl TaskLog {
    /// True when the item recorded nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Run `f` with this thread's recording redirected into a detached buffer,
/// returning the result and the buffer. `util::threadpool` wraps each work
/// item in this so spans recorded on worker threads can be re-attached to
/// the capturing thread in item order, independent of which worker ran
/// which item.
pub fn record_task<R>(f: impl FnOnce() -> R) -> (R, TaskLog) {
    let (prev_mode, prev_events) = LOG.with(|l| {
        let mut l = l.borrow_mut();
        let prev = (l.mode, std::mem::take(&mut l.events));
        l.mode = Mode::Task;
        prev
    });
    let r = f();
    let events = LOG.with(|l| {
        let mut l = l.borrow_mut();
        let events = std::mem::replace(&mut l.events, prev_events);
        l.mode = prev_mode;
        events
    });
    (r, TaskLog { events })
}

/// Append buffered worker-item events to the current thread's log in the
/// order given (callers pass item order, which makes the merged log
/// independent of worker count). No-op when this thread is not recording.
pub fn splice_tasks(logs: impl IntoIterator<Item = TaskLog>) {
    LOG.with(|l| {
        let mut l = l.borrow_mut();
        if l.mode == Mode::Off {
            return;
        }
        for t in logs {
            if t.events.is_empty() {
                continue;
            }
            l.events.push(Ev::TaskOpen);
            l.events.extend(t.events);
            l.events.push(Ev::TaskClose);
        }
    });
}
