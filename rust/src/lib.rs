//! # DFModel — design space optimization of large-scale systems exploiting
//! # dataflow mappings
//!
//! Reproduction of Ko, Zhang, Hsu, Pedram, Olukotun (Stanford, cs.AR 2024).
//!
//! DFModel maps workload dataflow graphs (kernels = vertices, tensors =
//! edges) onto hierarchical systems by optimizing at two levels:
//!
//! * **inter-chip** (§IV): TP/PP/DP parallelization degrees, per-kernel
//!   sharding strategies, and pipeline-stage assignment over the
//!   interconnection-network hierarchy — [`interchip`];
//! * **intra-chip** (§V): kernel fusion into sequentially-executed on-chip
//!   partitions under SRAM/DRAM constraints with compute-tile allocation —
//!   [`intrachip`].
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every paper table/figure to a module and bench target.
//!
//! The public entry point is the [`api`] facade: a declarative
//! [`api::Scenario`] in, a [`api::Report`] (with its [`api::Mapping`])
//! out. The optimizer internals stay `pub(crate)`.

pub mod api;
pub mod assign;
pub mod baselines;
pub mod cluster;
pub mod collective;
pub mod config;
pub mod daemon;
pub mod dse;
pub mod explain;
pub mod explore;
pub mod fabric;
pub mod figures;
pub mod graph;
pub mod interchip;
pub mod intrachip;
pub mod lint;
pub mod obs;
pub mod pipeline;
pub mod roofline;
pub mod runtime;
pub mod serving;
pub mod sharding;
pub mod solver;
pub mod system;
pub mod util;
