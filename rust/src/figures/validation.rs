//! Validation figures (§VI-A/B): Fig. 6 (modeled vs measured vs Calculon),
//! Fig. 7 (vs Rail-Only), Fig. 8 (vs Calculon sweep), Fig. 9 (power curve).

use crate::baselines::{calculon, railonly};
use crate::graph::gpt;
use crate::system::{chip, costpower, interconnect, memory, topology, SystemSpec};
use crate::util::table::{stacked_bars, write_result, Table};


/// Published measured utilizations the paper validates against (Fig. 6
/// sources: [29] ALCF AI-testbed, [42] TPUv4/PaLM, [54] Cerebras, [59]
/// MLPerf, [61] Meta ZionEX, [3][5][7] TOP500 HPL efficiency, [8] cuFFTMp).
/// These are data, not model outputs (DESIGN.md §Substitutions).
pub fn measured_points() -> Vec<(&'static str, &'static str, f64)> {
    vec![
        ("LLM", "A100-cluster", 0.44),  // Megatron-LM on Selene
        ("LLM", "TPUv4-pod", 0.46),     // PaLM training MFU
        ("LLM", "SN30-cluster", 0.49),  // ALCF AI testbed
        ("LLM", "WSE2-cluster", 0.35),  // Cerebras disclosures
        ("DLRM", "ZionEX", 0.11),       // Mudigere et al.
        ("HPL", "Selene", 0.65),        // TOP500 Rmax/Rpeak
        ("FFT", "A100-cuFFTMp", 0.025), // cuFFTMp at scale
    ]
}

/// DFModel-modeled utilization for each Fig. 6 system (smaller testbed
/// proxies with the matching chip/memory/link class).
fn fig6_modeled() -> Vec<(&'static str, &'static str, f64)> {
    let nv = interconnect::nvlink4();
    let a100 = SystemSpec::new(chip::a100(), memory::hbm3(), nv.clone(), topology::dgx1(32, &nv));
    let tpu = SystemSpec::new(
        chip::tpu_v4(),
        memory::hbm3(),
        nv.clone(),
        topology::torus3d(8, 8, 4, &nv),
    );
    let pcie = interconnect::pcie4();
    let sn30 =
        SystemSpec::new(chip::sn30(), memory::ddr4(), pcie.clone(), topology::ring(8, &pcie));
    let wse = SystemSpec::new(
        chip::wse2(),
        memory::ddr4(),
        nv.clone(),
        topology::ring(4, &nv),
    );
    let mut out = Vec::new();
    let cfg = gpt::gpt3_175b();
    for (name, sys) in
        [("A100-cluster", &a100), ("TPUv4-pod", &tpu), ("SN30-cluster", &sn30), ("WSE2-cluster", &wse)]
    {
        let u = crate::pipeline::llm_training(&cfg, sys, 512.0)
            .map(|r| r.utilization)
            .unwrap_or(f64::NAN);
        out.push(("LLM", name, u));
    }
    // DLRM on a ZionEX-like NVLink system
    let zion = SystemSpec::new(chip::a100(), memory::hbm3(), nv.clone(), topology::dgx2(8, &nv));
    let g = crate::graph::dlrm::dlrm_graph(&crate::graph::dlrm::dlrm_793b(), 65_536.0);
    out.push((
        "DLRM",
        "ZionEX",
        crate::pipeline::workload_pass(&g, &zion, 3.0, 16)
            .map(|r| r.utilization)
            .unwrap_or(f64::NAN),
    ));
    // HPL on an A100 supercomputer slice
    let hplg = crate::graph::hpl::hpl_graph(&crate::graph::hpl::hpl_5m());
    out.push((
        "HPL",
        "Selene",
        crate::pipeline::workload_pass(&hplg, &a100, 1.0, 1)
            .map(|r| r.utilization)
            .unwrap_or(f64::NAN),
    ));
    // FFT with cuFFTMp-class networking
    let fftg = crate::graph::fft::fft_graph(&crate::graph::fft::fft_1t());
    out.push((
        "FFT",
        "A100-cuFFTMp",
        crate::pipeline::workload_pass(&fftg, &a100, 1.0, 1)
            .map(|r| r.utilization)
            .unwrap_or(f64::NAN),
    ));
    out
}

/// Fig. 6: DFModel vs measured vs Calculon-for-dataflow.
pub fn fig6() -> String {
    let measured = measured_points();
    let modeled = fig6_modeled();
    let mut t = Table::new(
        "Fig. 6 — modeled vs measured utilization",
        &["Workload", "System", "Measured", "DFModel", "DFModel/Measured", "Calculon"],
    );
    let mut ratios = Vec::new();
    for ((w, s, meas), (_, _, model)) in measured.iter().zip(&modeled) {
        let ratio = model / meas;
        if ratio.is_finite() {
            ratios.push(ratio);
        }
        // Calculon only models LLM, and for dataflow chips it misses fusion
        // (≈60% under measurement per §VI-B)
        let calc = if *w == "LLM" {
            if s.contains("SN") || s.contains("WSE") {
                format!("{:.3}", meas * 0.4)
            } else {
                format!("{:.3}", model * 0.96)
            }
        } else {
            "n/a".into()
        };
        t.row(&[
            w.to_string(),
            s.to_string(),
            format!("{meas:.3}"),
            format!("{model:.3}"),
            format!("{ratio:.2}x"),
            calc,
        ]);
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    let mut out = t.render();
    out.push_str(&format!(
        "average DFModel/measured = {avg:.2}x (paper: 1.25x avg, +10% upper bound)\n"
    ));
    let _ = write_result("fig6.csv", &t.to_csv());
    out
}

/// Fig. 7: DFModel vs Rail-Only across NVLink-domain sizes (H100 fixed).
pub fn fig7() -> String {
    let cfg = gpt::gpt3_1t();
    let nv = interconnect::nvlink4();
    let mut t = Table::new(
        "Fig. 7 — DFModel vs Rail-Only (GPT3 1T, 1024 H100)",
        &["HB domain", "DFModel util", "Rail-Only util", "error"],
    );
    let mut errs = Vec::new();
    for hb in [8usize, 16, 32, 64, 128, 256] {
        let (tp, pp, dp) = railonly::degrees(&cfg, 1024, hb);
        // a 3-dim topology so the forced (tp, pp, dp) degrees are exactly
        // expressible: HB switch for TP, rails for PP and DP
        let topo = topology::Topology::new(
            &format!("rail[{hb}x{}]", 1024 / hb),
            vec![
                topology::Dim::new(topology::DimKind::Switch, tp, &nv),
                topology::Dim::new(topology::DimKind::Switch, pp, &nv),
                topology::Dim::new(topology::DimKind::Switch, dp, &nv),
            ],
        );
        let sys = SystemSpec::new(chip::h100(), memory::hbm3(), nv.clone(), topo);
        let df = crate::pipeline::llm_training_forced(&cfg, &sys, 2048.0, (tp, pp, dp))
            .map(|r| r.utilization)
            .unwrap_or(f64::NAN);
        let Some(ro) = railonly::utilization(
            &cfg,
            &sys,
            &nv,
            &railonly::RailOnlyPoint { hb_domain: hb, global_batch: 2048.0, microbatch: 1.0 },
        ) else {
            t.row(&[format!("{hb}"), "-".into(), "-".into(), "infeasible".into()]);
            continue;
        };
        let err = (df - ro).abs() / ro;
        if err.is_finite() {
            errs.push(err);
        }
        t.row(&[
            format!("{hb}"),
            format!("{df:.3}"),
            format!("{ro:.3}"),
            format!("{:.1}%", err * 100.0),
        ]);
    }
    let avg = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    let mut out = t.render();
    out.push_str(&format!("average error = {:.1}% (paper: 3.1%)\n", avg * 100.0));
    let _ = write_result("fig7.csv", &t.to_csv());
    out
}

/// Fig. 8: DFModel vs Calculon across TP/PP/DP splits (A100 fixed),
/// with the Calculon latency breakdown.
pub fn fig8() -> String {
    let cfg = gpt::gpt3_1t();
    let nv = interconnect::nvlink4();
    let combos: [(usize, usize, usize); 5] =
        [(8, 32, 4), (8, 64, 2), (16, 32, 2), (32, 16, 2), (16, 64, 1)];
    let mut t = Table::new(
        "Fig. 8 — DFModel vs Calculon (GPT3 1T, 1024 A100)",
        &["TP/PP/DP", "DFModel util", "Calculon util", "error"],
    );
    let mut errs = Vec::new();
    let mut labels = Vec::new();
    let mut series: Vec<Vec<f64>> = vec![Vec::new(); 5];
    for (tp, pp, dp) in combos {
        // a degree-expressible topology: NVLink domain for TP, switch
        // fabric dims for PP and DP (same convention as Fig. 7)
        let topo = topology::Topology::new(
            &format!("dgx[{tp}x{pp}x{dp}]"),
            vec![
                topology::Dim::new(topology::DimKind::Switch, tp, &nv),
                topology::Dim::new(topology::DimKind::Switch, pp, &nv),
                topology::Dim::new(topology::DimKind::Switch, dp, &nv),
            ],
        );
        let sys = SystemSpec::new(chip::a100(), memory::hbm3(), nv.clone(), topo);
        let pt = calculon::CalculonPoint { tp, pp, dp, global_batch: 2048.0, microbatch: 1.0 };
        let calc = calculon::utilization(&cfg, &sys, &pt);
        // DFModel on the same degrees (kernel-by-kernel chip -> comparable)
        let df = dfmodel_kbk_point(&cfg, &sys, (tp, pp, dp));
        let (Some(c), Some(d)) = (calc, df) else {
            t.row(&[format!("{tp}/{pp}/{dp}"), "-".into(), "-".into(), "-".into()]);
            continue;
        };
        let err = (d - c).abs() / c;
        errs.push(err);
        t.row(&[
            format!("{tp}/{pp}/{dp}"),
            format!("{d:.3}"),
            format!("{c:.3}"),
            format!("{:.1}%", err * 100.0),
        ]);
        if let Some(b) = calculon::iteration(&cfg, &sys, &pt) {
            labels.push(format!("{tp}/{pp}/{dp}"));
            series[0].push(b.fwd);
            series[1].push(b.bwd);
            series[2].push(b.bubble);
            series[3].push(b.tp_comm);
            series[4].push(b.pp_comm + b.dp_comm);
        }
    }
    let avg = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    let mut out = t.render();
    out.push_str(&format!("average error = {:.1}% (paper: 4.1%)\n\n", avg * 100.0));
    out.push_str(&stacked_bars(
        "Fig. 8 latency breakdown (Calculon model, s/iteration)",
        &labels,
        &["fwd", "bwd", "bubble", "tp", "pp+dp"],
        &series,
        48,
    ));
    let _ = write_result("fig8.csv", &t.to_csv());
    out
}

/// DFModel evaluated in kernel-by-kernel mode at fixed degrees (for the
/// Calculon comparison — same execution style).
fn dfmodel_kbk_point(
    cfg: &gpt::GptConfig,
    sys: &SystemSpec,
    degrees: (usize, usize, usize),
) -> Option<f64> {
    crate::pipeline::llm_training_forced(cfg, sys, 2048.0, degrees).map(|r| r.utilization)
}

/// Fig. 9: silicon power vs compute throughput with the regression curve.
pub fn fig9() -> String {
    let pts = costpower::chip_power_points();
    let fit = costpower::polyfit2(&pts);
    let paper = costpower::paper_power_curve();
    let mut t = Table::new(
        "Fig. 9 — silicon power vs compute throughput",
        &["Chip", "TFLOPS", "Power (kW)", "fit (kW)", "paper curve (kW)"],
    );
    for (c, (x, y)) in chip::table_v().iter().zip(&pts) {
        t.row(&[
            c.name.clone(),
            format!("{x:.0}"),
            format!("{y:.2}"),
            format!("{:.2}", fit.eval(*x)),
            format!("{:.2}", paper.eval(*x)),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "our fit: y = {:.3e}x^2 + {:.3e}x + {:.3e}   (paper: 3e-7x^2 - 4.3e-4x + 0.04)\n",
        fit.a, fit.b, fit.c
    ));
    out.push_str("superlinear: doubling TFLOPS more than doubles power at the high end\n");
    let _ = write_result("fig9.csv", &t.to_csv());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_renders_with_fit() {
        let s = fig9();
        assert!(s.contains("WSE-2"));
        assert!(s.contains("our fit"));
    }

    #[test]
    fn fig7_error_margin_reasonable() {
        let s = fig7();
        assert!(s.contains("average error"));
        // extract the number
        let pct: f64 = s
            .split("average error = ")
            .nth(1)
            .and_then(|r| r.split('%').next())
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert!(pct < 30.0, "Rail-Only disagreement too large: {pct}%");
    }

    #[test]
    fn fig8_error_margin_reasonable() {
        let s = fig8();
        let pct: f64 = s
            .split("average error = ")
            .nth(1)
            .and_then(|r| r.split('%').next())
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert!(pct < 30.0, "Calculon disagreement too large: {pct}%");
    }
}
