//! Cluster-simulation figure: the request-level extension of Fig. 20 —
//! goodput, latency percentiles, and KV pressure vs offered load for one
//! Llama3-8B replica on 16 SN40L, under the simulator's continuous-batching
//! scheduler.

use crate::cluster::engine::{simulate, ReplicaConfig, Slo};
use crate::cluster::workload::TraceSpec;
use crate::graph::llama;
use crate::serving;
use crate::util::table::{write_result, Table};
use crate::util::units::fmt_time;

/// Offered-load sweep on one replica: the goodput knee appears where the
/// prefill-bound capacity of the slow RDU fabric saturates.
pub fn fig_cluster() -> String {
    let cfg = ReplicaConfig::new(llama::llama3_8b(), serving::sn40l_x16(), 16, 1);
    let slo = Slo { ttft: 1.0, tpot: 0.02 };
    let mut t = Table::new(
        "Cluster sim — Llama3 8B, one 16xSN40L replica (SLO: TTFT 1 s, TPOT 20 ms)",
        &["offered rps", "attain", "goodput rps", "TTFT p50", "TTFT p99", "TPOT p99", "KV peak"],
    );
    for rate in [2.0, 5.0, 10.0, 20.0, 40.0] {
        let requests = TraceSpec::poisson(11, rate, 200).generate();
        let r = simulate(&cfg, 1, &requests, &slo).expect("16xSN40L fits Llama3 8B");
        t.row(&[
            format!("{rate:.0}"),
            format!("{:.1}%", r.slo_attainment * 100.0),
            format!("{:.2}", r.goodput_rps),
            fmt_time(r.ttft.p50),
            fmt_time(r.ttft.p99),
            fmt_time(r.tpot.p99),
            format!("{:.1}%", r.kv_peak_frac * 100.0),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "(one replica saturates where network-bound prefill exhausts the step budget;\n\
         beyond the knee TTFT queues grow and goodput falls below the offered load)\n",
    );
    let _ = write_result("fig_cluster.csv", &t.to_csv());
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig_cluster_renders_the_sweep() {
        let s = super::fig_cluster();
        assert!(s.contains("Cluster sim"));
        assert!(s.contains("offered rps"));
        // all five load points render
        for rate in ["2", "5", "10", "20", "40"] {
            assert!(s.contains(&format!("| {rate}")), "missing load row {rate}");
        }
    }
}
