//! §VII case study: GPT3 175B on eight SN10 RDUs — Fig. 18 (hierarchical
//! roofline of four mappings), Table VI (speedup chain), Fig. 19
//! (dataflow vs non-dataflow over the SRAM × DRAM-bandwidth space).

use crate::graph::gpt::{self, GptConfig};
use crate::interchip::{self, InterChipOptions};
use crate::intrachip::{self, IntraChipOptions};
use crate::roofline::Roofline;
use crate::system::{chip, interconnect, memory, topology, SystemSpec};
use crate::util::error::Result;
use crate::util::table::{write_result, Heatmap, Table};
use crate::{bail, err};

/// One evaluated §VII mapping.
#[derive(Debug, Clone)]
pub struct MappingResult {
    pub name: String,
    /// Per-layer pipeline-input time on one chip (s).
    pub time: f64,
    /// Per-chip useful FLOP per input.
    pub flops: f64,
    /// Per-chip DRAM traffic per input (bytes).
    pub dram_bytes: f64,
    /// Per-chip network traffic time-equivalent denominator (bytes).
    pub net_bytes: f64,
    pub n_partitions: usize,
}

impl MappingResult {
    pub fn throughput(&self) -> f64 {
        self.flops / self.time
    }
}

/// The §VII system: 8 SN10, DDR 200 GB/s, PCIe 25 GB/s.
pub fn sn10_system(topo_name: &str) -> Result<SystemSpec> {
    let link = interconnect::pcie4();
    let topo = match topo_name {
        "ring8" => topology::ring(8, &link),
        "torus4x2" => topology::torus2d(4, 2, &link),
        other => bail!("unknown §VII topology '{other}' (expected ring8|torus4x2)"),
    };
    let mut mem = memory::ddr4();
    mem.capacity = crate::util::units::Bytes::new(3e12); // SN10 pairs with large DDR (§VII: "large-capacity")
    Ok(SystemSpec::new(chip::sn10(), mem, link, topo))
}

/// The vendor 4-partition assignment of §VII-B, by kernel name.
pub fn vendor_partition_of(name: &str) -> usize {
    match name.rsplit('.').next().unwrap_or(name) {
        "LN1" | "Q" | "K" | "V" => 0,
        "MHA1" | "Softmax" | "MHA2" | "Proj" | "Add1" => 1,
        "LN2" | "FFN0" | "GeLU" => 2,
        _ => 3, // FFN1, Add2
    }
}

/// The DFModel-optimized 4-partition assignment of §VII-C: Proj co-located
/// with FFN0 so the Proj all-reduce overlaps the FFN0 GEMM.
pub fn dfmodel_partition_of(name: &str) -> usize {
    match name.rsplit('.').next().unwrap_or(name) {
        "LN1" | "Q" | "K" | "V" => 0,
        "MHA1" | "Softmax" | "MHA2" => 1,
        "Proj" | "Add1" | "LN2" | "FFN0" | "GeLU" => 2,
        _ => 3, // FFN1, Add2
    }
}

/// Evaluate one mapping variant on the §VII system.
fn eval_mapping(
    name: &str,
    cfg: &GptConfig,
    sys: &SystemSpec,
    degrees: (usize, usize, usize),
    force_kbk: bool,
    force_vendor: bool,
) -> Result<MappingResult> {
    let fine = gpt::gpt_layer_graph(cfg, 1.0);
    let plans = interchip::enumerate_plans(&sys.topology);
    let plan = plans
        .iter()
        .find(|p| (p.tp, p.pp, p.dp) == degrees)
        .ok_or_else(|| err!("no plan {degrees:?} in {}", sys.topology.name))?;
    let (schemes, _) = interchip::optimizer::select_sharding(
        &fine,
        sys,
        plan,
        &InterChipOptions::default(),
    );
    let (sharded, net_time) = interchip::shard_graph(&fine, sys, plan, &schemes);

    let mut opts = IntraChipOptions { net_time, ..Default::default() };
    if force_kbk {
        opts.force_kernel_by_kernel = true;
    }
    if force_vendor {
        let part: Vec<usize> =
            sharded.kernels.iter().map(|k| vendor_partition_of(&k.name)).collect();
        opts.force_assignment = Some(part);
    }
    let intra = intrachip::optimize_intra(&sharded, &sys.chip, &sys.memory, &opts)
        .ok_or_else(|| err!("infeasible intra-chip mapping for '{name}'"))?;

    let flops = sharded.total_flops();
    let net_total: f64 = opts_net_total(&intra, &sharded, sys);
    Ok(MappingResult {
        name: name.into(),
        time: intra.total_time,
        flops,
        dram_bytes: intra.total_dram_traffic().max(1.0),
        net_bytes: net_total.max(1.0),
        n_partitions: intra.assignment.n_used(),
    })
}

fn opts_net_total(
    intra: &intrachip::IntraChipMapping,
    _g: &crate::graph::DataflowGraph,
    sys: &SystemSpec,
) -> f64 {
    // network bytes equivalent: t_net × link bandwidth
    intra.partitions.iter().map(|p| p.t_net).sum::<f64>() * sys.link.bandwidth.raw()
}

/// All four §VII mappings in Table VI order. Errors (rather than panicking
/// or silently dropping entries) when a plan is missing or infeasible.
pub fn four_mappings() -> Result<Vec<MappingResult>> {
    let cfg = gpt::gpt3_175b();
    let ring = sn10_system("ring8")?;
    let torus = sn10_system("torus4x2")?;
    Ok(vec![
        eval_mapping(
            "non-dataflow (Calculon-style), 8x1 ring",
            &cfg,
            &ring,
            (8, 1, 1),
            true,
            false,
        )?,
        eval_mapping("vendor dataflow mapping, 8x1 ring", &cfg, &ring, (8, 1, 1), false, true)?,
        eval_mapping("DFModel dataflow mapping, 8x1 ring", &cfg, &ring, (8, 1, 1), false, false)?,
        eval_mapping(
            "DFModel dataflow mapping, 4x2 torus",
            &cfg,
            &torus,
            (4, 1, 2),
            false,
            false,
        )?,
    ])
}

/// Fig. 18 + Table VI.
pub fn fig18_table6() -> Result<String> {
    let maps = four_mappings()?;
    let sys = sn10_system("ring8")?;
    let rl = Roofline::of_system(&sys);

    let mut t18 = Table::new(
        "Fig. 18 — hierarchical roofline (per SN10 chip, DDR+PCIe)",
        &["Mapping", "OI_mem (FLOP/B)", "OI_net (FLOP/B)", "achieved", "attainable", "bound"],
    );
    for m in &maps {
        let p = rl.point(
            &m.name,
            crate::util::units::Flop::new(m.flops),
            crate::util::units::Bytes::new(m.dram_bytes),
            crate::util::units::Bytes::new(m.net_bytes),
            crate::util::units::Seconds::new(m.time),
        );
        let att = rl.attainable(p.oi_mem, p.oi_net);
        t18.row(&[
            m.name.clone(),
            format!("{:.1}", p.oi_mem),
            format!("{:.1}", p.oi_net),
            crate::util::units::fmt_flops(p.achieved),
            crate::util::units::fmt_flops(att.raw()),
            format!("{:?}", rl.bound(p.oi_mem, p.oi_net)),
        ]);
    }

    let mut t6 = Table::new(
        "Table VI — mapping speedup chain",
        &["Mapping", "partitions", "stepwise speedup", "accum. speedup", "paper accum."],
    );
    let paper = [1.0, 4.05, 4.8, 6.13];
    let base = maps[0].throughput();
    let mut prev = base;
    for (i, m) in maps.iter().enumerate() {
        let thr = m.throughput();
        t6.row(&[
            m.name.clone(),
            format!("{}", m.n_partitions),
            format!("{:.2}x", thr / prev),
            format!("{:.2}x", thr / base),
            format!("{:.2}x", paper.get(i).copied().unwrap_or(f64::NAN)),
        ]);
        prev = thr;
    }
    let mut out = t18.render();
    out.push('\n');
    out.push_str(&t6.render());
    let _ = write_result("fig18_table6.csv", &t6.to_csv());
    Ok(out)
}

/// Fig. 19: dataflow vs non-dataflow utilization over SRAM × DRAM bw.
pub fn fig19() -> String {
    let cells = crate::dse::fig19_sweep();
    let srams = ["150MB", "300MB", "500MB"];
    let bws = ["100GB/s", "300GB/s", "600GB/s"];
    let mut df = Heatmap::new("Fig. 19 — dataflow mapping utilization", &srams, &bws);
    let mut kbk = Heatmap::new("Fig. 19 — non-dataflow mapping utilization", &srams, &bws);
    let mut max_ratio = 0.0f64;
    for c in &cells {
        let r = match c.sram_mb as usize {
            150 => 0,
            300 => 1,
            _ => 2,
        };
        let col = match c.dram_gbs as usize {
            100 => 0,
            300 => 1,
            _ => 2,
        };
        df.set(r, col, c.dataflow_util);
        kbk.set(r, col, c.non_dataflow_util);
        if c.dataflow_util.is_finite() && c.non_dataflow_util.is_finite() {
            max_ratio = max_ratio.max(c.dataflow_util / c.non_dataflow_util);
        }
    }
    let mut out = df.render();
    out.push('\n');
    out.push_str(&kbk.render());
    out.push_str(&format!(
        "\ndataflow is an upper bound of non-dataflow; max advantage {max_ratio:.2}x (paper 1.63x)\n"
    ));
    let _ = write_result("fig19.csv", &df.to_csv());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vendor_partition_matches_section_vii_b() {
        assert_eq!(vendor_partition_of("L0.Q"), 0);
        assert_eq!(vendor_partition_of("L0.Proj"), 1);
        assert_eq!(vendor_partition_of("L0.FFN0"), 2);
        assert_eq!(vendor_partition_of("L0.Add2"), 3);
    }

    #[test]
    fn unknown_topology_is_an_error_not_a_panic() {
        let e = sn10_system("hypercube").unwrap_err();
        assert!(e.to_string().contains("hypercube"), "{e}");
    }

    #[test]
    fn speedup_chain_is_monotone() {
        // non-dataflow < vendor < DFModel ring <= DFModel torus (§VII)
        let maps = four_mappings().expect("all four mappings must be feasible");
        assert_eq!(maps.len(), 4, "all four mappings must be present");
        let thr: Vec<f64> = maps.iter().map(|m| m.throughput()).collect();
        assert!(thr[1] > thr[0], "vendor must beat non-dataflow: {thr:?}");
        assert!(thr[2] >= thr[1] * 0.999, "DFModel must match/beat vendor: {thr:?}");
        assert!(thr[3] >= thr[2] * 0.999, "torus must match/beat ring: {thr:?}");
        // headline: DFModel total speedup over non-dataflow is large
        let total = thr[3] / thr[0];
        assert!(total > 2.0, "accumulated speedup too small: {total:.2}x (paper 6.13x)");
    }

    #[test]
    fn non_dataflow_mapping_is_memory_bound() {
        let maps = four_mappings().unwrap();
        let sys = sn10_system("ring8").unwrap();
        let rl = crate::roofline::Roofline::of_system(&sys);
        let m = &maps[0];
        let p = rl.point(
            &m.name,
            crate::util::units::Flop::new(m.flops),
            crate::util::units::Bytes::new(m.dram_bytes),
            crate::util::units::Bytes::new(m.net_bytes),
            crate::util::units::Seconds::new(m.time),
        );
        assert_eq!(rl.bound(p.oi_mem, p.oi_net), crate::roofline::Bound::Memory);
    }

    #[test]
    fn dataflow_raises_memory_oi() {
        let maps = four_mappings().unwrap();
        let oi = |m: &MappingResult| m.flops / m.dram_bytes;
        assert!(oi(&maps[1]) > 2.0 * oi(&maps[0]), "fusion must raise OI_mem substantially");
    }
}
