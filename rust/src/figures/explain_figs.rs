//! The "explain" figure: per-hierarchy-level bottleneck attribution bars
//! for the four §VI-C paper workloads on their reference systems, plus the
//! top kernels of each — the explain layer's reproduction of the paired
//! latency-breakdown figures (Figs. 11/13/15/17) with exact second-level
//! shares instead of normalized fractions.

use crate::api::{Scenario, SystemCfg};
use crate::bail;
use crate::util::error::Result;
use crate::util::table::{stacked_bars, write_result, Table};
use std::fmt::Write as _;

/// The four §VI-C training workloads on their 1024-chip torus reference
/// systems (the same grid points the DSE sweep evaluates). The returned
/// scenario has the explain layer armed; sensitivity is left to callers.
pub fn paper_scenario(w: &str) -> Result<Scenario> {
    let mut s = match w {
        "llm" => Scenario::llm("gpt3-1t")
            .batch(2048.0)
            .on(SystemCfg::new("h100", "hbm3", "nvlink4").torus2d(32, 32)),
        "dlrm" => Scenario::dlrm().on(SystemCfg::new("sn30", "hbm3", "nvlink4").torus2d(32, 32)),
        "hpl" => Scenario::hpl().on(SystemCfg::new("tpuv4", "ddr4", "pcie4").torus2d(32, 32)),
        "fft" => Scenario::fft().on(SystemCfg::new("tpuv4", "hbm3", "nvlink4").torus2d(32, 32)),
        other => bail!("unknown workload '{other}' (known: llm dlrm hpl fft)"),
    };
    s.explain.enabled = true;
    Ok(s)
}

/// Generate the figure: one stacked bar per workload (compute / sram /
/// dram / interchip / bubble seconds) plus the top-3 kernels of each, and
/// the `explain.csv` artifact. Workloads whose reference point is
/// infeasible degrade to an annotated line instead of failing the figure.
pub fn explain_figure() -> Result<String> {
    let mut labels: Vec<String> = Vec::new();
    let mut series: Vec<Vec<f64>> = vec![Vec::new(); 5];
    let mut kernel_lines = String::new();
    let mut skipped = String::new();
    let mut t = Table::new(
        "",
        &[
            "workload", "total_s", "binding", "compute_s", "sram_s", "dram_s", "interchip_s",
            "bubble_s",
        ],
    );
    for w in ["llm", "dlrm", "hpl", "fft"] {
        let mut s = paper_scenario(w)?;
        // attribution + audit only: the finite-difference sweep would
        // re-evaluate each workload several more times for no figure gain
        s.explain.sensitivity = false;
        let attr = match s.evaluate() {
            Ok(r) => r.explain.and_then(|e| e.attribution),
            Err(e) => {
                let _ = writeln!(skipped, "  {w}: infeasible on the reference system ({e})");
                continue;
            }
        };
        let Some(a) = attr else { continue };
        labels.push(w.to_string());
        for (slot, v) in series.iter_mut().zip([
            a.levels.compute,
            a.levels.sram,
            a.levels.dram,
            a.levels.interchip,
            a.levels.bubble,
        ]) {
            slot.push(v);
        }
        for k in a.kernels.iter().take(3) {
            let _ = writeln!(
                kernel_lines,
                "  {w:<5} {:<24} {:>6.2}% ({})",
                k.name,
                100.0 * k.seconds / a.total.max(1e-30),
                k.bound
            );
        }
        t.row(&[
            w.to_string(),
            format!("{}", a.total),
            a.binding.to_string(),
            format!("{}", a.levels.compute),
            format!("{}", a.levels.sram),
            format!("{}", a.levels.dram),
            format!("{}", a.levels.interchip),
            format!("{}", a.levels.bubble),
        ]);
    }
    if labels.is_empty() {
        bail!("explain figure: no paper workload was feasible on its reference system");
    }
    let mut out = stacked_bars(
        "explain: per-level step-time attribution (seconds)",
        &labels,
        &["compute", "sram", "dram", "interchip", "bubble"],
        &series,
        30,
    );
    out.push_str("\ntop kernels per workload:\n");
    out.push_str(&kernel_lines);
    if !skipped.is_empty() {
        out.push_str("\nskipped workloads:\n");
        out.push_str(&skipped);
    }
    let _ = write_result("explain.csv", &t.to_csv());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scenarios_parse_and_arm_explain() {
        for w in ["llm", "dlrm", "hpl", "fft"] {
            let s = paper_scenario(w).expect("known workload");
            assert!(s.explain.enabled);
            s.check().expect("reference scenario validates");
        }
        assert!(paper_scenario("nope").is_err());
    }
}
