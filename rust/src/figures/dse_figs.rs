//! DSE heat maps + latency breakdowns (Figs 10–17): one generator per
//! workload producing three heat maps (utilization, cost efficiency, power
//! efficiency) over chips × (topology, memory, link), plus the stacked
//! compute/memory/network breakdown.

use crate::dse::{sweep, DesignPoint, Workload};
use crate::util::table::{stacked_bars, write_result, Heatmap, Table};

fn col_label(p: &DesignPoint) -> String {
    let topo = p.topo.split('[').next().unwrap_or(&p.topo);
    format!("{topo}|{}|{}", p.mem, p.link)
}

/// Generate the heat maps + breakdown for one workload (e.g. Fig. 10/11).
pub fn dse_figure(w: Workload) -> String {
    let points = sweep(w);
    render(w, &points)
}

fn render(w: Workload, points: &[DesignPoint]) -> String {
    let mut chips: Vec<String> = Vec::new();
    let mut cols: Vec<String> = Vec::new();
    for p in points {
        if !chips.contains(&p.chip) {
            chips.push(p.chip.clone());
        }
        let c = col_label(p);
        if !cols.contains(&c) {
            cols.push(c);
        }
    }
    let chip_refs: Vec<&str> = chips.iter().map(|s| s.as_str()).collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();

    let mut util = Heatmap::new(&format!("{} utilization", w.name()), &chip_refs, &col_refs);
    let mut cost =
        Heatmap::new(&format!("{} cost efficiency (GFLOP/s/$)", w.name()), &chip_refs, &col_refs);
    let mut power =
        Heatmap::new(&format!("{} power efficiency (GFLOP/s/W)", w.name()), &chip_refs, &col_refs);
    for p in points {
        let r = chips.iter().position(|c| *c == p.chip).unwrap();
        let c = cols.iter().position(|c| *c == col_label(p)).unwrap();
        util.set(r, c, p.utilization);
        cost.set(r, c, p.cost_eff);
        power.set(r, c, p.power_eff);
    }

    // latency breakdown per design point (the paired odd-numbered figure)
    let labels: Vec<String> =
        points.iter().map(|p| format!("{}|{}", p.chip, col_label(p))).collect();
    let series = vec![
        points.iter().map(|p| p.breakdown.0).collect::<Vec<_>>(),
        points.iter().map(|p| p.breakdown.1).collect::<Vec<_>>(),
        points.iter().map(|p| p.breakdown.2).collect::<Vec<_>>(),
    ];

    let mut out = String::new();
    out.push_str(&util.render());
    out.push('\n');
    out.push_str(&cost.render());
    out.push('\n');
    out.push_str(&power.render());
    out.push('\n');
    out.push_str(&stacked_bars(
        &format!("{} latency breakdown (fractions)", w.name()),
        &labels,
        &["compute", "memory", "network"],
        &series,
        30,
    ));
    out.push_str(&key_observations(w, points));

    let id = match w {
        Workload::Llm => "fig10",
        Workload::Dlrm => "fig12",
        Workload::Hpl => "fig14",
        Workload::Fft => "fig16",
    };
    let mut t = Table::new(
        "",
        &["chip", "topo", "mem", "link", "util", "cost_eff", "power_eff", "comp", "memf", "netf"],
    );
    for p in points {
        t.row(&[
            p.chip.clone(),
            p.topo.clone(),
            p.mem.clone(),
            p.link.clone(),
            format!("{}", p.utilization),
            format!("{}", p.cost_eff),
            format!("{}", p.power_eff),
            format!("{}", p.breakdown.0),
            format!("{}", p.breakdown.1),
            format!("{}", p.breakdown.2),
        ]);
    }
    let _ = write_result(&format!("{id}.csv"), &t.to_csv());
    out
}

/// The "explore" figure: the §VI-C LLM grid run through the pruning
/// explorer — coverage counters, the Pareto frontier over (utilization,
/// cost efficiency, power efficiency), and the dataflow headline ratios.
/// Rendering is shared with the `Explore` goal's CLI report
/// (`ExploreReport::render`); this adds only the CSV artifact.
pub fn explore_figure() -> crate::util::error::Result<String> {
    use crate::api::ExploreReport;
    use crate::explore::{explore, ExploreSettings, SearchSpace};
    let out = explore(&SearchSpace::paper_grid(Workload::Llm), &ExploreSettings::default())?;
    let rep = ExploreReport::from_outcome(&out, out.frontier.len());
    let _ = write_result("explore.csv", &rep.frontier_table().to_csv());
    Ok(rep.render())
}

/// Aggregate ratios mirroring the paper's §VI-C bullet lists.
pub fn key_observations(w: Workload, points: &[DesignPoint]) -> String {
    let finite = |v: f64| v.is_finite();
    let mean = |sel: &dyn Fn(&&DesignPoint) -> bool, f: &dyn Fn(&DesignPoint) -> f64| -> f64 {
        let vals: Vec<f64> =
            points.iter().filter(|p| sel(p)).map(|p| f(p)).filter(|v| finite(*v)).collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    };
    let util = |sel: &dyn Fn(&&DesignPoint) -> bool| mean(sel, &|p| p.utilization);
    let is_rdu = |p: &&DesignPoint| p.chip == "SN30";
    let is_gputpu = |p: &&DesignPoint| p.chip == "H100" || p.chip == "TPUv4";
    let is_wse = |p: &&DesignPoint| p.chip == "WSE-2";
    let nvl = |p: &&DesignPoint| p.link == "NVLink4";
    let pcie = |p: &&DesignPoint| p.link == "PCIe4";
    let dragonfly = |p: &&DesignPoint| p.topo.contains("dragonfly");

    let mut s = String::from("\nkey ratios (cf. §VI-C observations):\n");
    match w {
        Workload::Llm => {
            s += &format!(
                "  RDU/(GPU+TPU) utilization: {:.2}x (paper 1.52x)\n",
                util(&is_rdu) / util(&is_gputpu)
            );
            let gpu_hbm = util(&|p: &&DesignPoint| is_gputpu(p) && p.mem == "HBM3");
            let gpu_ddr = util(&|p: &&DesignPoint| is_gputpu(p) && p.mem == "DDR4");
            s += &format!("  GPU/TPU HBM vs DDR: {:.2}x (paper 1.66x)\n", gpu_hbm / gpu_ddr);
            let rdu_hbm = util(&|p: &&DesignPoint| is_rdu(p) && p.mem == "HBM3");
            let rdu_ddr = util(&|p: &&DesignPoint| is_rdu(p) && p.mem == "DDR4");
            s += &format!("  RDU HBM vs DDR: {:.2}x (paper ~1.0x)\n", rdu_hbm / rdu_ddr);
            let wse_nv = util(&|p: &&DesignPoint| is_wse(p) && nvl(p));
            let wse_pc = util(&|p: &&DesignPoint| is_wse(p) && pcie(p));
            s += &format!("  WSE NVLink vs PCIe: {:.2}x (paper 5.15x)\n", wse_nv / wse_pc);
        }
        Workload::Dlrm | Workload::Fft => {
            s += &format!(
                "  NVLink vs PCIe utilization: {:.2}x (paper {} )\n",
                util(&nvl) / util(&pcie),
                if w == Workload::Dlrm { "6.3x" } else { "7.02x" }
            );
            let df_pc = util(&|p: &&DesignPoint| dragonfly(p) && pcie(p));
            let simple_pc = util(&|p: &&DesignPoint| !dragonfly(p) && pcie(p));
            s += &format!(
                "  dragonfly vs simple (PCIe): {:.2}x (paper {})\n",
                df_pc / simple_pc,
                if w == Workload::Dlrm { "2.51x" } else { "3.22x" }
            );
            let tpu = util(&|p: &&DesignPoint| p.chip == "TPUv4");
            let rest = util(&|p: &&DesignPoint| p.chip != "TPUv4");
            s += &format!(
                "  TPU (slowest chip) vs others: {:.2}x (paper {})\n",
                tpu / rest,
                if w == Workload::Dlrm { "4.43x" } else { "5.11x" }
            );
            s += &format!("  WSE vs others: {:.2}x (paper ~0.1x)\n", util(&is_wse)
                / util(&|p: &&DesignPoint| !is_wse(p)));
        }
        Workload::Hpl => {
            s += &format!("  overall mean utilization: {:.2} (paper: high everywhere)\n", util(&|_| true));
            let wse_cost = mean(&is_wse, &|p| p.cost_eff)
                / mean(&|p: &&DesignPoint| !is_wse(p), &|p| p.cost_eff);
            s += &format!("  WSE cost efficiency vs others: {:.2}x (paper 0.09x)\n", wse_cost);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: full sweeps run in the bench targets; here we only exercise the
    // rendering path on a small synthetic set to keep unit tests fast.
    fn fake_points() -> Vec<DesignPoint> {
        let mut v = Vec::new();
        for chip in ["H100", "TPUv4", "SN30", "WSE-2"] {
            for link in ["PCIe4", "NVLink4"] {
                v.push(DesignPoint {
                    chip: chip.into(),
                    topo: "2D-torus[32x32]".into(),
                    mem: "HBM3".into(),
                    link: link.into(),
                    dataflow: chip == "SN30" || chip == "WSE-2",
                    utilization: if chip == "SN30" { 0.5 } else { 0.3 },
                    cost_eff: 1.0,
                    power_eff: 1.0,
                    achieved_flops: 1e15,
                    breakdown: (0.5, 0.3, 0.2),
                });
            }
        }
        v
    }

    #[test]
    fn render_produces_heatmaps_and_observations() {
        let s = super::render(Workload::Llm, &fake_points());
        assert!(s.contains("utilization"));
        assert!(s.contains("key ratios"));
        assert!(s.contains("RDU/(GPU+TPU)"));
    }

    #[test]
    fn observations_compute_ratios() {
        let s = key_observations(Workload::Llm, &fake_points());
        assert!(s.contains("1.67x") || s.contains("1.66x") || s.contains("1.6"), "{s}");
    }
}
