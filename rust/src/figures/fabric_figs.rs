//! Fabric figure: analytical vs fabric-simulated collective time across the
//! five paper topology families (§VI-C), at 64 chips so the sweep stays
//! interactive. The headline is the DGX-1 row — the analytical model's
//! fully-connected shortcut for the intra-node dim is ~4× optimistic once
//! the real hybrid cube-mesh serializes the traffic — while the
//! torus/dragonfly/DGX-2 hierarchies land within a few percent of the
//! BlueConnect formulas (and the simulator sometimes *beats* them by using
//! links the phase-per-dim decomposition leaves idle).

use crate::collective::{self, Collective};
use crate::fabric::{self, SimConfig};
use crate::system::interconnect;
use crate::system::topology::{self, Dim, Topology};
use crate::util::table::{write_result, Table};
use crate::util::units::fmt_time;

/// The five families reduced to 64 chips each.
fn fabric_topologies() -> Vec<Topology> {
    let link = interconnect::nvlink4();
    vec![
        topology::torus2d(8, 8, &link),
        topology::torus3d(4, 4, 4, &link),
        topology::dragonfly(8, 8, &link),
        topology::dgx1(8, &link),
        topology::dgx2(4, &link),
    ]
}

pub fn fig_fabric() -> String {
    let bytes = 64e6;
    let cfg = SimConfig::default();
    let mut t = Table::new(
        "Fabric — AllReduce 64 MB/chip, five 64-chip topologies (NVLink4)",
        &["topology", "analytical", "simulated", "algo", "sim/ana", "max-link", "msgs", "bisect"],
    );
    for topo in fabric_topologies() {
        let g = fabric::FabricGraph::new(&topo);
        let group: Vec<usize> = (0..topo.n_chips()).collect();
        let dims: Vec<&Dim> = topo.dims.iter().collect();
        let ana =
            collective::time_hier(Collective::AllReduce, crate::util::units::Bytes::new(bytes), &dims)
                .raw();
        let b = fabric::best(&g, &group, Collective::AllReduce, bytes, &cfg)
            .expect("every topology runs at least one algorithm");
        t.row(&[
            topo.name.clone(),
            fmt_time(ana),
            fmt_time(b.time),
            b.algo.name().to_string(),
            format!("{:.2}x", b.time / ana),
            format!("{:.0}%", b.max_link_util * 100.0),
            format!("{}", b.msgs),
            format!("{:.1} TB/s", topo.bisection_bytes_per_s().raw() / 1e12),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "(sim/ana near 1.00: the BlueConnect formulas are certified by simulation;\n\
         DGX-1's ratio quantifies the fully-connected shortcut's optimism against\n\
         the true 16-edge hybrid cube-mesh; ratios below 1 mean the best simulated\n\
         algorithm exploits links the phase-per-dim analytical decomposition idles)\n",
    );
    let _ = write_result("fig_fabric.csv", &t.to_csv());
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fabric_figure_renders_all_five_topologies() {
        let s = super::fig_fabric();
        for name in ["2D-torus", "3D-torus", "dragonfly", "DGX-1", "DGX-2"] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
        assert!(s.contains("bisect") && s.contains("TB/s"));
    }

    #[test]
    fn dgx1_row_exposes_the_cube_mesh_gap() {
        use crate::collective::{self, Collective};
        use crate::fabric::{self, SimConfig};
        use crate::system::{interconnect, topology};
        let link = interconnect::nvlink4();
        let topo = topology::dgx1(8, &link);
        let g = fabric::FabricGraph::new(&topo);
        let group: Vec<usize> = (0..64).collect();
        let dims: Vec<&topology::Dim> = topo.dims.iter().collect();
        let ana =
            collective::time_hier(Collective::AllReduce, crate::util::units::Bytes::new(64e6), &dims)
                .raw();
        let b = fabric::best(&g, &group, Collective::AllReduce, 64e6, &SimConfig::default())
            .unwrap();
        assert!(b.time > 2.0 * ana, "cube-mesh gap vanished: sim {} vs ana {ana}", b.time);
    }
}
