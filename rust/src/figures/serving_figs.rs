//! §VIII case-study figures: Fig. 20 (Llama3 8B serving on 16 SN40L),
//! Fig. 21 (speculative decoding sweeps), Fig. 22 (3-D memory).

use crate::graph::llama;
use crate::serving::{self, specdecode, ServingPoint};
use crate::util::table::{write_result, Heatmap, Table};
use crate::util::units::fmt_time;

/// Fig. 20: TTFT / prefill throughput / TPOT / decode throughput across
/// TP×PP splits of 16 chips.
pub fn fig20() -> String {
    let model = llama::llama3_8b();
    let sys = serving::sn40l_x16();
    let combos = [(16usize, 1usize), (8, 2), (4, 4), (2, 8), (1, 16)];
    let mut t = Table::new(
        "Fig. 20 — Llama3 8B on 16 SN40L",
        &["TP/PP", "TTFT", "prefill tok/s", "TPOT", "decode tok/s", "decode bound"],
    );
    for (tp, pp) in combos {
        let m = serving::evaluate(
            &model,
            &sys,
            &ServingPoint { tp, pp, batch: 1.0, prompt_len: 1024.0, context: 1024.0 },
        )
        .expect("every Fig. 20 split covers the 16-chip group");
        let (c, mem, net) = m.decode_breakdown;
        let bound = if mem >= net && mem >= c {
            "memory"
        } else if net >= c {
            "network"
        } else {
            "compute"
        };
        t.row(&[
            format!("{tp}/{pp}"),
            fmt_time(m.ttft),
            format!("{:.0}", m.prefill_tps),
            fmt_time(m.tpot),
            format!("{:.0}", m.decode_tps),
            bound.into(),
        ]);
    }
    let v = serving::evaluate(
        &model,
        &sys,
        &ServingPoint { tp: 16, pp: 1, batch: 1.0, prompt_len: 1024.0, context: 1024.0 },
    )
    .expect("TP=16/PP=1 covers the 16-chip group");
    let mut out = t.render();
    out.push_str(&format!(
        "validation: TP=16/PP=1 decode = {:.0} tok/s (paper model 1188, measured 1100; our error vs measured {:.0}%)\n",
        v.decode_tps,
        (v.decode_tps - 1100.0).abs() / 1100.0 * 100.0
    ));
    let _ = write_result("fig20.csv", &t.to_csv());
    out
}

/// Fig. 21: sequence- vs tree-based speculative decoding sweeps
/// (draft ∈ {68M, 8B, 70B} → target Llama3 405B on 16 SN40L).
pub fn fig21() -> String {
    let sys = serving::sn40l_x16();
    let target = llama::llama3_405b();
    let drafts: [(&str, llama::LlamaConfig); 3] = [
        ("68M", llama::llama_68m()),
        ("8B", llama::llama3_8b()),
        ("70B", llama::llama3_70b()),
    ];
    let windows = [1usize, 2, 4, 6, 8];
    let accepts = [0.6, 0.7, 0.8, 0.9];
    let wlabels: Vec<String> = windows.iter().map(|w| format!("K={w}")).collect();
    let alabels: Vec<String> = accepts.iter().map(|a| format!("a={a}")).collect();
    let wrefs: Vec<&str> = wlabels.iter().map(|s| s.as_str()).collect();
    let arefs: Vec<&str> = alabels.iter().map(|s| s.as_str()).collect();

    let mut out = String::new();
    let mut best: Vec<(String, f64)> = Vec::new();
    for scheme in [specdecode::Scheme::Sequence, specdecode::Scheme::Tree] {
        for (dname, draft) in &drafts {
            let title = format!(
                "Fig. 21 — {:?}-based, draft {dname} -> 405B (tok/s)",
                scheme
            );
            let mut hm = Heatmap::new(&title, &arefs, &wrefs);
            let mut peak = 0.0f64;
            for (r, &a) in accepts.iter().enumerate() {
                for (c, &w) in windows.iter().enumerate() {
                    let tps = specdecode::throughput(
                        draft,
                        &target,
                        &sys,
                        &specdecode::SpecDecodePoint { window: w, acceptance: a, scheme },
                    );
                    hm.set(r, c, tps);
                    peak = peak.max(tps);
                }
            }
            out.push_str(&hm.render());
            out.push('\n');
            best.push((format!("{scheme:?}/{dname}"), peak));
        }
    }
    out.push_str("peak tok/s per (scheme, draft):\n");
    for (k, v) in &best {
        out.push_str(&format!("  {k}: {v:.0}\n"));
    }
    let _ = write_result(
        "fig21.csv",
        &best.iter().map(|(k, v)| format!("{k},{v}\n")).collect::<String>(),
    );
    out
}

/// Fig. 22: achieved 100T-GPT training throughput vs compute-area fraction
/// under three memory generations.
pub fn fig22() -> String {
    let cells = crate::dse::fig22_sweep();
    let mems = ["2D-DDR", "2.5D-HBM", "3D-stacked"];
    let pcts = ["20%", "35%", "50%", "65%", "80%"];
    let mut hm = Heatmap::new(
        "Fig. 22 — 100T GPT achieved FLOP/s vs compute-area %",
        &mems,
        &pcts,
    );
    for c in &cells {
        let r = mems.iter().position(|m| *m == c.mem_name).unwrap();
        let col = match (c.compute_pct * 100.0).round() as usize {
            20 => 0,
            35 => 1,
            50 => 2,
            65 => 3,
            _ => 4,
        };
        hm.set(r, col, c.achieved / 1e15); // PFLOP/s
    }
    let mut out = hm.render();
    out.push_str("(values in PFLOP/s; best column shifts right as memory bandwidth grows)\n");
    let _ = write_result("fig22.csv", &hm.to_csv());
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig20_renders_and_validates() {
        let s = super::fig20();
        assert!(s.contains("TP/PP"));
        assert!(s.contains("validation"));
        assert!(s.contains("16/1"));
    }

    #[test]
    fn fig21_has_all_six_heatmaps() {
        let s = super::fig21();
        assert_eq!(s.matches("Fig. 21 —").count(), 6);
        assert!(s.contains("Sequence-based, draft 68M"));
        assert!(s.contains("Tree-based, draft 70B"));
    }
}
