//! `cargo bench --bench optimizer_perf`
//!
//! Micro/meso benchmarks of the optimizer hot paths (the §Perf targets in
//! EXPERIMENTS.md): sharding selection, stage-partition DP, intra-chip
//! fusion DP, a single DSE design-point evaluation, and the end-to-end
//! 80-point sweep. The paper's scale reference: a trillion-parameter LLM
//! onto 1024 accelerators, full joint space, in 20 min on 64 CPUs.

use dfmodel::api::{self, Scenario, SystemCfg};
use dfmodel::graph::gpt::{gpt3_175b, gpt3_1t, gpt_coarse_graph, gpt_layer_graph};
use dfmodel::interchip::{self, InterChipOptions};
use dfmodel::intrachip::IntraChipOptions;
use dfmodel::system::{chip, interconnect, memory, topology, SystemSpec};
use dfmodel::util::bench::{quick_mode, Runner};

fn main() {
    let mut r = Runner::new();

    // ---- inter-chip: sharding selection on the fine layer graph ----
    let link = interconnect::pcie4();
    let sys8 = SystemSpec::new(
        chip::sn10(),
        memory::ddr4(),
        link.clone(),
        topology::ring(8, &link),
    );
    let fine = gpt_layer_graph(&gpt3_175b(), 1.0);
    let plans = interchip::enumerate_plans(&sys8.topology);
    let plan8 = plans.iter().find(|p| p.tp == 8).unwrap().clone();
    r.run("sharding_selection(fine layer, tp=8)", 2, 10, || {
        let _ = interchip::optimizer::select_sharding(
            &fine,
            &sys8,
            &plan8,
            &InterChipOptions::default(),
        );
    });

    // ---- inter-chip: full optimize on the coarse 1T graph, 1024 chips ----
    let nv = interconnect::nvlink4();
    let sys1024 = SystemSpec::new(
        chip::h100(),
        memory::hbm3(),
        nv.clone(),
        topology::torus2d(32, 32, &nv),
    );
    let coarse = gpt_coarse_graph(&gpt3_1t(), 1.0);
    r.run("interchip_optimize(GPT3-1T coarse, 1024 chips)", 1, 3, || {
        let _ = api::map_graph(&coarse, &sys1024, &InterChipOptions::default());
    });

    // ---- intra-chip fusion DP on the sharded layer ----
    let (sharded, net_time) =
        interchip::shard_graph(&fine, &sys8, &plan8, &vec![1; fine.n_kernels()]);
    r.run("intrachip_optimize(sharded layer, SN10)", 2, 10, || {
        let _ = api::map_chip(
            &sharded,
            &sys8.chip,
            &sys8.memory,
            &IntraChipOptions { net_time: net_time.clone(), ..Default::default() },
        );
    });

    // ---- one LLM design point end to end ----
    r.run("llm_design_point(GPT3-1T, 1024 H100)", 1, 3, || {
        let _ = dfmodel::pipeline::llm_training(&gpt3_1t(), &sys1024, 2048.0);
    });

    // ---- the facade end to end: Scenario -> Report (guards the api
    // overhead over the raw pipeline call above) ----
    let scenario = Scenario::llm("gpt3-175b")
        .batch(64.0)
        .on(SystemCfg::new("sn10", "ddr4", "pcie4").ring(8));
    r.run("scenario_evaluate(GPT3-175B, 8xSN10 ring)", 1, 5, || {
        let _ = scenario.evaluate();
    });

    // ---- the full 80-point LLM DSE sweep (the paper's headline run;
    // skipped in DFMODEL_BENCH_QUICK CI mode) ----
    if !quick_mode() {
        r.run("dse_sweep(GPT3-1T, 80 systems)", 0, 1, || {
            let _ = dfmodel::dse::sweep(dfmodel::dse::Workload::Llm);
        });
    }

    // ---- serving + spec-decode models (cheap, but tracked) ----
    r.run("serving_grid(fig20)", 1, 5, || {
        let _ = dfmodel::figures::serving_figs::fig20();
    });

    let _ = dfmodel::util::table::write_result("optimizer_perf.txt", &r.summary());
    let _ = r.write_json("optimizer_perf");
    println!("\n{}", r.summary());
}
