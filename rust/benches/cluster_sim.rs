//! `cargo bench --bench cluster_sim`
//!
//! Tracks the discrete-event engine's throughput (events/sec) so scheduler
//! regressions are visible: a saturated single replica, a 4-replica
//! cluster, the streaming calendar-queue path (events/s and requests/s,
//! gated in ci/bench_baseline.json), and one full planner sweep.

use dfmodel::cluster::engine::{simulate, simulate_stream, ReplicaConfig, SimOptions, Slo};
use dfmodel::cluster::planner::{plan, PlanTarget, PlanTraffic};
use dfmodel::cluster::workload::TraceSpec;
use dfmodel::graph::llama::{llama3_70b, llama3_8b};
use dfmodel::serving::sn40l_x16;
use dfmodel::util::bench::Runner;

fn main() {
    let mut r = Runner::new();
    let slo = Slo { ttft: 2.0, tpot: 0.05 };

    let cfg = ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1);
    let requests = TraceSpec::poisson(7, 40.0, 2000).generate();
    let mut events = 0u64;
    r.run("engine(8B, 1 replica, 2000 reqs, saturated)", 1, 5, || {
        events = simulate(&cfg, 1, &requests, &slo).expect("feasible").events;
    });
    let secs = r.results.last().unwrap().min.as_secs_f64().max(1e-12);
    println!("  -> event-loop throughput: {:.0} events/s ({events} events)", events as f64 / secs);

    r.run("engine(8B, 4 replicas, 2000 reqs)", 1, 5, || {
        events = simulate(&cfg, 4, &requests, &slo).expect("feasible").events;
    });
    let secs = r.results.last().unwrap().min.as_secs_f64().max(1e-12);
    println!("  -> event-loop throughput: {:.0} events/s ({events} events)", events as f64 / secs);

    // streaming path: calendar queue + arena + P² summaries, trace never
    // materialized. One probe run fixes the event count for the events/s
    // column; the gate watches both events/s and requests/s.
    let opts = SimOptions::default();
    let fleet_spec = TraceSpec::poisson(7, 64.0, 20_000);
    let probe = simulate_stream(&cfg, 8, &fleet_spec, &slo, &opts).expect("feasible");
    r.run_with_items(
        "engine-stream(8B, fleet 8 @64rps, 20k reqs) events",
        1,
        3,
        probe.events as f64,
        || {
            simulate_stream(&cfg, 8, &fleet_spec, &slo, &opts).expect("feasible");
        },
    );
    println!(
        "  -> streaming fleet run: {} events | {} in-flight peak",
        probe.events, probe.peak_in_flight
    );

    let single_spec = TraceSpec::poisson(9, 8.0, 10_000);
    r.run_with_items(
        "engine-stream(8B, 1 replica @8rps, 10k reqs) requests",
        1,
        3,
        single_spec.n_requests as f64,
        || {
            simulate_stream(&cfg, 1, &single_spec, &slo, &opts).expect("feasible");
        },
    );

    let target = PlanTarget { qps: 2.0, slo, attainment: 0.9 };
    let traffic = PlanTraffic { n_requests: 200, ..Default::default() };
    let best = r.run_once("planner(70B, full catalog sweep)", || {
        plan(&llama3_70b(), &target, &traffic).best
    });
    println!("  -> planner found a fleet: {}", best.is_some());

    let _ = dfmodel::util::table::write_result("cluster_sim.txt", &r.summary());
    let _ = r.write_json("cluster_sim");
    println!("\n{}", r.summary());
}
