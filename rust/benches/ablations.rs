//! `cargo bench --bench ablations`
//!
//! Ablation studies for the design choices DESIGN.md calls out:
//!   A1  hierarchical (BlueConnect) vs flat collectives
//!   A2  exhaustive vs coordinate-descent sharding selection (quality gap)
//!   A3  exact min-max stage DP vs greedy equal-FLOP partitioning
//!   A4  tile water-filling vs even split (critical-time gap)
//!   A5  kernel-by-kernel efficiency derate sensitivity (Table VI chain)

use dfmodel::api;
use dfmodel::collective::{time, time_hier, Collective};
use dfmodel::util::units::Bytes;
use dfmodel::graph::gpt::{gpt3_175b, gpt3_1t, gpt_coarse_graph, gpt_layer_graph};
use dfmodel::interchip::{self, InterChipOptions};
use dfmodel::intrachip::tiles::allocate_tiles;
use dfmodel::system::topology::{Dim, DimKind};
use dfmodel::system::{chip, interconnect, memory, topology, SystemSpec};
use dfmodel::util::prng::Rng;
use dfmodel::util::table::{write_result, Table};

fn main() {
    let mut out = String::new();
    out.push_str(&a1_hier_vs_flat());
    out.push_str(&a2_sharding_quality());
    out.push_str(&a3_stage_dp_vs_greedy());
    out.push_str(&a4_waterfill_vs_even());
    out.push_str(&a5_derate_sensitivity());
    println!("{out}");
    let _ = write_result("ablations.txt", &out);
}

/// A1: hierarchical all-reduce over composed dims vs one flat ring.
fn a1_hier_vs_flat() -> String {
    let nv = interconnect::nvlink4();
    let mut t = Table::new(
        "A1 — hierarchical vs flat all-reduce (1 GB payload)",
        &["chips", "flat ring (ms)", "hier 2-D (ms)", "speedup"],
    );
    for n in [64usize, 256, 1024] {
        let side = (n as f64).sqrt() as usize;
        let flat = Dim::new(DimKind::Ring, n, &nv);
        let d1 = Dim::new(DimKind::Ring, side, &nv);
        let d2 = Dim::new(DimKind::Ring, side, &nv);
        let tf = time(Collective::AllReduce, Bytes::new(1e9), &flat).raw();
        let th = time_hier(Collective::AllReduce, Bytes::new(1e9), &[&d1, &d2]).raw();
        t.row(&[
            format!("{n}"),
            format!("{:.3}", tf * 1e3),
            format!("{:.3}", th * 1e3),
            format!("{:.2}x", tf / th),
        ]);
    }
    t.render() + "\n"
}

/// A2: the CD heuristic must match exhaustive sharding on graphs small
/// enough to enumerate.
fn a2_sharding_quality() -> String {
    let link = interconnect::pcie4();
    let sys = SystemSpec::new(
        chip::sn10(),
        memory::ddr4(),
        link.clone(),
        topology::ring(8, &link),
    );
    let g = gpt_layer_graph(&gpt3_175b(), 1.0);
    let plans = interchip::enumerate_plans(&sys.topology);
    let plan = plans.iter().find(|p| p.tp == 8).unwrap();
    // exhaustive (space below threshold)
    let exact = interchip::optimizer::select_sharding(
        &g,
        &sys,
        plan,
        &InterChipOptions { exhaustive_below: 1e12, ..Default::default() },
    );
    // coordinate descent only
    let cd = interchip::optimizer::select_sharding(
        &g,
        &sys,
        plan,
        &InterChipOptions { exhaustive_below: 0.0, ..Default::default() },
    );
    let cost = |labels: &[usize]| {
        let v = interchip::latency_vectors(&g, &sys, plan, labels);
        v.h_n.iter().sum::<f64>() + v.h_m.iter().sum::<f64>() + v.h_c.iter().sum::<f64>()
    };
    let (ce, cc) = (cost(&exact.0), cost(&cd.0));
    format!(
        "A2 — sharding selection quality (GPT layer, tp=8):\n  exhaustive {:.6e}s  coordinate-descent {:.6e}s  gap {:.3}%\n\n",
        ce,
        cc,
        (cc / ce - 1.0) * 100.0
    )
}

/// A3: exact stage DP vs greedy equal-count stage split on the coarse 1T
/// graph with heterogeneous per-layer times.
fn a3_stage_dp_vs_greedy() -> String {
    let nv = interconnect::nvlink4();
    let sys = SystemSpec::new(
        chip::a100(),
        memory::hbm3(),
        nv.clone(),
        topology::Topology::new(
            "dp-test",
            vec![
                Dim::new(DimKind::Switch, 16, &nv),
                Dim::new(DimKind::Switch, 16, &nv),
                Dim::new(DimKind::Switch, 4, &nv),
            ],
        ),
    );
    let g = gpt_coarse_graph(&gpt3_1t(), 1.0);
    let opts = InterChipOptions { force_degrees: Some((16, 16, 4)), ..Default::default() };
    let m = api::map_graph(&g, &sys, &opts).expect("feasible");
    // greedy: equal layer counts
    let per = g.n_kernels() / 16;
    let greedy_worst = m
        .vectors
        .h_c
        .chunks(per)
        .map(|c| c.iter().sum::<f64>())
        .fold(0.0f64, f64::max);
    format!(
        "A3 — stage partitioning (GPT3-1T, tp=16 pp=16): DP max-stage {:.4e}s vs equal-split compute {:.4e}s (DP <= greedy: {})\n\n",
        m.t_cri.raw(),
        greedy_worst,
        m.t_cri.raw() <= greedy_worst * 1.0001
    )
}

/// A4: water-filling tile allocation vs even split across random kernels.
fn a4_waterfill_vs_even() -> String {
    let mut rng = Rng::new(99);
    let mut worst_gain: f64 = 1.0;
    let mut mean_gain = 0.0;
    let trials = 200;
    for _ in 0..trials {
        let n = 2 + rng.below(10);
        let total = n + rng.below(600);
        let f: Vec<f64> = (0..n).map(|_| rng.uniform(1.0, 1e9)).collect();
        let (_, crit) = allocate_tiles(&f, total).unwrap();
        let mut even = vec![total / n; n];
        for t in even.iter_mut().take(total % n) {
            *t += 1;
        }
        let crit_even = (0..n).map(|i| f[i] / even[i] as f64).fold(0.0f64, f64::max);
        let gain = crit_even / crit.max(1e-30);
        worst_gain = worst_gain.max(gain);
        mean_gain += gain / trials as f64;
    }
    format!(
        "A4 — tile water-filling vs even split ({trials} random partitions): mean {mean_gain:.2}x, max {worst_gain:.2}x faster critical kernel\n\n"
    )
}

/// A5: sensitivity of the Table VI speedup chain to the kernel-by-kernel
/// efficiency derate (documents the §Perf modeling choice).
fn a5_derate_sensitivity() -> String {
    // run the four-mapping §VII study and report the accumulated speedup
    let maps = match dfmodel::figures::casestudy::four_mappings() {
        Ok(m) => m,
        Err(e) => return format!("A5 — skipped ({e})\n\n"),
    };
    let base = maps[0].throughput();
    let accum = maps.last().unwrap().throughput() / base;
    let vendor = maps[1].throughput() / base;
    let mut s = String::from("A5 — Table VI chain under the 0.62 kbk derate:\n");
    s.push_str(&format!(
        "  vendor/non-dataflow {vendor:.2}x, total {accum:.2}x (paper 4.05x / 6.13x)\n"
    ));
    s.push_str("  (the derate scales the non-dataflow baseline; the DP mappings are unaffected)\n\n");
    s
}
