//! `cargo bench --bench fabric_sim`
//!
//! Tracks the fabric simulator's throughput (simulated messages/sec and
//! packet-hop events/sec) so event-loop regressions are visible: ring and
//! direct all-reduces on a 4×4 torus, a 64-chip hierarchical all-reduce,
//! and one full algorithm-selection sweep.

use dfmodel::collective::Collective;
use dfmodel::fabric::{build, simulate, Algo, FabricGraph, SimConfig};
use dfmodel::system::{interconnect, topology};
use dfmodel::util::bench::Runner;

fn main() {
    let link = interconnect::nvlink4();
    let mut r = Runner::new();
    let cfg = SimConfig::default();

    let t16 = topology::torus2d(4, 4, &link);
    let g16 = FabricGraph::new(&t16);
    let grp16: Vec<usize> = (0..16).collect();
    let mut stats = (0usize, 0u64);
    for algo in [Algo::Ring, Algo::Direct] {
        let sched = build(&g16, algo, Collective::AllReduce, &grp16, 64e6).unwrap();
        r.run(&format!("sim(torus4x4, AR 64MB, {})", algo.name()), 3, 10, || {
            let res = simulate(&g16, &sched, &cfg);
            stats = (res.msgs, res.events);
        });
        let secs = r.results.last().unwrap().min.as_secs_f64().max(1e-12);
        println!(
            "  -> {:.0} msgs/s | {:.0} events/s ({} msgs, {} events)",
            stats.0 as f64 / secs,
            stats.1 as f64 / secs,
            stats.0,
            stats.1
        );
    }

    let t64 = topology::torus3d(4, 4, 4, &link);
    let g64 = FabricGraph::new(&t64);
    let grp64: Vec<usize> = (0..64).collect();
    let sched = build(&g64, Algo::Hier, Collective::AllReduce, &grp64, 64e6).unwrap();
    r.run("sim(torus4x4x4, AR 64MB, hier)", 3, 10, || {
        let res = simulate(&g64, &sched, &cfg);
        stats = (res.msgs, res.events);
    });
    let secs = r.results.last().unwrap().min.as_secs_f64().max(1e-12);
    println!(
        "  -> {:.0} msgs/s | {:.0} events/s ({} msgs, {} events)",
        stats.0 as f64 / secs,
        stats.1 as f64 / secs,
        stats.0,
        stats.1
    );

    let n = r.run_once("select(torus4x4, AR, 4 algos x 2 payloads)", || {
        let mut count = 0;
        for bytes in [32e3, 256e6] {
            count +=
                dfmodel::fabric::evaluate_algos(&g16, &grp16, Collective::AllReduce, bytes, &cfg)
                    .len();
        }
        count
    });
    println!("  -> {n} algorithm evaluations");

    let _ = dfmodel::util::table::write_result("fabric_sim.txt", &r.summary());
    let _ = r.write_json("fabric_sim");
    println!("\n{}", r.summary());
}
