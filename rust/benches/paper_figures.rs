//! `cargo bench --bench paper_figures [-- <figure-id ...>|--all]`
//!
//! Regenerates every table and figure of the paper's evaluation (the full
//! DESIGN.md per-experiment index), printing each and timing its
//! generation. Output is also written to results/*.csv and the combined
//! text to results/paper_figures.txt.

use dfmodel::figures;
use dfmodel::util::bench::Runner;
use dfmodel::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // cargo passes "--bench"; ignore it
    let mut ids: Vec<String> = args
        .positional
        .iter()
        .chain(args.subcommand.iter())
        .filter(|s| *s != "--bench" && !s.starts_with("--"))
        .cloned()
        .collect();
    if ids.is_empty() || args.has_flag("all") {
        ids = figures::ALL.iter().map(|s| s.to_string()).collect();
    }

    let mut runner = Runner::new();
    let mut combined = String::new();
    for id in &ids {
        let out = runner.run_once(&format!("figure::{id}"), || {
            figures::generate(id).unwrap_or_else(|e| format!("figure '{id}' failed: {e}"))
        });
        println!("{out}");
        combined.push_str(&format!("===== {id} =====\n{out}\n"));
    }
    combined.push_str("\n===== generation times =====\n");
    combined.push_str(&runner.summary());
    let _ = dfmodel::util::table::write_result("paper_figures.txt", &combined);
    let _ = runner.write_json("paper_figures");
    println!("\n{}", runner.summary());
}
