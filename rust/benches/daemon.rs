//! `cargo bench --bench daemon`
//!
//! Daemon serving throughput through a real loopback socket: scenarios/s
//! for `POST /v1/evaluate` with the result cache disabled (every request
//! runs the full optimizer) vs enabled and warmed (every request is an LRU
//! hit — HTTP parse + canonicalization + cache probe only). The cached
//! path must stay >= 10× the uncached path; both entries are gated by
//! `dfmodel bench-check` via ci/bench_baseline.json.

use std::path::Path;

use dfmodel::daemon::{http, Config, Server, ServiceConfig};
use dfmodel::util::bench::{quick_mode, Runner};

fn scenario_text() -> String {
    let path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios/llm_dgx.json");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn server(cache_entries: usize) -> dfmodel::daemon::Handle {
    let cfg = Config {
        addr: "127.0.0.1:0".parse().unwrap(),
        service: ServiceConfig { workers: 2, cache_entries, ..ServiceConfig::default() },
        ..Config::default()
    };
    Server::bind(&cfg).expect("bind").start().expect("start")
}

fn main() {
    let mut r = Runner::new();
    let iters = if quick_mode() { 2 } else { 5 };
    let text = scenario_text();

    let uncached = server(0);
    let per_iter = if quick_mode() { 2usize } else { 5 };
    r.run_with_items("evaluate_llm_dgx_uncached", 1, iters, per_iter as f64, || {
        for _ in 0..per_iter {
            let (status, _) =
                http::roundtrip(uncached.addr(), "POST", "/v1/evaluate", Some(&text))
                    .expect("roundtrip");
            assert_eq!(status, 200);
        }
    });
    uncached.stop().expect("clean stop");

    let cached = server(256);
    // warm the single entry so the measured loop is all hits
    let (status, _) = http::roundtrip(cached.addr(), "POST", "/v1/evaluate", Some(&text))
        .expect("warmup");
    assert_eq!(status, 200);
    let hits = if quick_mode() { 50usize } else { 200 };
    r.run_with_items("evaluate_llm_dgx_cached", 1, iters, hits as f64, || {
        for _ in 0..hits {
            let (status, _) =
                http::roundtrip(cached.addr(), "POST", "/v1/evaluate", Some(&text))
                    .expect("roundtrip");
            assert_eq!(status, 200);
        }
    });
    cached.stop().expect("clean stop");

    // acceptance contract: cached serving >= 10× uncached scenarios/s
    let tp = |name: &str| {
        r.results
            .iter()
            .find(|e| e.name == name)
            .and_then(|e| e.throughput)
            .expect("throughput recorded")
    };
    let (cold, warm) = (tp("evaluate_llm_dgx_uncached"), tp("evaluate_llm_dgx_cached"));
    assert!(
        warm >= 10.0 * cold,
        "cached throughput must be >= 10x uncached: {warm:.2}/s vs {cold:.2}/s"
    );

    let _ = dfmodel::util::table::write_result("daemon.txt", &r.summary());
    let _ = r.write_json("daemon");
    println!("\n{}", r.summary());
    println!("cached/uncached speedup: {:.1}x", warm / cold);
}
