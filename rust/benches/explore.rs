//! `cargo bench --bench explore`
//!
//! Explorer throughput (candidates/s) with and without bound pruning on a
//! 64-point LLM space, plus the pruned §VI-C paper grid — the headline
//! entries of the CI bench-regression gate (results/bench_explore.json →
//! BENCH_5.json vs ci/bench_baseline.json).

use dfmodel::dse::Workload;
use dfmodel::explore::{explore, ChipCfg, ExploreSettings, MemCfg, SearchSpace, WorkloadSpec};
use dfmodel::graph::gpt::GptConfig;
use dfmodel::util::bench::{quick_mode, Runner};

/// A 64-candidate space over a 16-layer GPT: catalog chips plus a ladder of
/// high-compute low-SRAM kernel-by-kernel parts the pruner can discard.
fn bench_space() -> SearchSpace {
    let cfg = GptConfig {
        layers: 16,
        d_model: 2048.0,
        n_heads: 16.0,
        seq: 1024.0,
        d_ff: 8192.0,
        vocab: 50257.0,
        dtype_bytes: 2.0,
    };
    let mut chips = vec![ChipCfg::named("sn30"), ChipCfg::named("h100"), ChipCfg::named("tpuv4")];
    for i in 0..5usize {
        chips.push(ChipCfg::Custom {
            name: format!("kbk-{i}"),
            compute_tflops: 1000.0 + 700.0 * i as f64,
            sram_mb: 24.0,
            dataflow: false,
            tiles: None,
            power_w: None,
            price_usd: None,
        });
    }
    SearchSpace {
        workload: WorkloadSpec {
            kind: Workload::Llm,
            gpt: Some(cfg),
            batch: Some(64.0),
            state_bytes_per_weight_byte: None,
        },
        chips,
        mems: vec![MemCfg::named("hbm3"), MemCfg::named("ddr4")],
        links: vec!["nvlink4".into(), "pcie4".into()],
        topologies: vec!["torus2d".into(), "ring".into()],
        chip_counts: vec![16],
        batches: vec![None],
    }
}

fn main() {
    let mut r = Runner::new();
    let space = bench_space();
    let n = space.candidates().expect("bench space is valid").len();
    let iters = if quick_mode() { 1 } else { 3 };

    for (name, prune) in [("explore_pruned", true), ("explore_exhaustive", false)] {
        let settings = ExploreSettings { prune, ..Default::default() };
        r.run_with_items(&format!("{name}({n} candidates, 16 chips)"), 0, iters, n as f64, || {
            let out = explore(&space, &settings).expect("explore runs");
            assert!(!out.frontier.is_empty());
        });
    }

    // the §VI-C LLM grid through the pruning explorer (paper scale; skipped
    // in DFMODEL_BENCH_QUICK CI mode)
    if !quick_mode() {
        let grid = SearchSpace::paper_grid(Workload::Llm);
        let settings = ExploreSettings::default();
        r.run_with_items("explore_paper_grid(GPT3-1T, 80 systems)", 0, 1, 80.0, || {
            let out = explore(&grid, &settings).expect("explore runs");
            assert!(!out.frontier.is_empty());
        });
    }

    let _ = dfmodel::util::table::write_result("explore.txt", &r.summary());
    let _ = r.write_json("explore");
    println!("\n{}", r.summary());
}
