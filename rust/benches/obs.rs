//! `cargo bench --bench obs`
//!
//! Instrumentation overhead: the same `Scenario` evaluated with tracing
//! off (the default path — one relaxed atomic load per probe) and with a
//! full span/metric capture armed, plus the raw disabled-probe rate. The
//! disabled-path entry is the bench-regression gate's <5% contract
//! (results/bench_obs.json → BENCH_7.json vs ci/bench_baseline.json).

use dfmodel::api::Scenario;
use dfmodel::util::bench::{quick_mode, Runner};

fn main() {
    let mut r = Runner::new();
    let iters = if quick_mode() { 2 } else { 8 };
    let s = Scenario::llm("gpt3-175b");

    r.run("evaluate_gpt3_175b_tracing_disabled", 1, iters, || {
        let rep = s.evaluate().expect("feasible");
        assert!(rep.stats.is_none());
    });

    let traced = s.clone().traced();
    r.run("evaluate_gpt3_175b_tracing_enabled", 1, iters, || {
        let rep = traced.evaluate().expect("feasible");
        assert!(rep.stats.is_some());
    });

    // raw disabled-probe throughput: spans + counters with no capture armed
    // must stay in the tens-of-nanoseconds range
    let probes = 1_000_000usize;
    r.run_with_items("span_counter_probes_disabled", 1, iters, probes as f64, || {
        for i in 0..probes {
            let _g = dfmodel::obs::span("noop");
            dfmodel::obs::counter("noop.count", i as u64);
        }
    });

    let _ = dfmodel::util::table::write_result("obs.txt", &r.summary());
    let _ = r.write_json("obs");
    println!("\n{}", r.summary());
}
