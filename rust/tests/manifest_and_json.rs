//! Coverage for the zero-dependency substrates the runtime leans on:
//! `util::json` round-trips (escapes, nesting, number edge cases) and
//! `runtime::manifest` error paths (malformed manifests must produce the
//! internal `Error`, never a panic).

use dfmodel::runtime::Manifest;
use dfmodel::util::json::Json;

// ---------------------------------------------------------------------------
// util::json
// ---------------------------------------------------------------------------

fn roundtrip(src: &str) -> Json {
    let v = Json::parse(src).expect(src);
    let compact = Json::parse(&v.to_string()).expect("reparse compact");
    assert_eq!(v, compact, "compact round-trip of {src}");
    let pretty = Json::parse(&v.pretty()).expect("reparse pretty");
    assert_eq!(v, pretty, "pretty round-trip of {src}");
    v
}

#[test]
fn json_roundtrips_escapes() {
    let v = roundtrip(r#"{"s": "line\nbreak\ttab \"quoted\" back\\slash \u0041 é 😀"}"#);
    assert_eq!(
        v.get("s").unwrap().as_str().unwrap(),
        "line\nbreak\ttab \"quoted\" back\\slash A é 😀"
    );
    // control characters survive a serialize→parse cycle
    let ctl = Json::Str("\u{1}\u{2}".to_string());
    assert_eq!(Json::parse(&ctl.to_string()).unwrap(), ctl);
}

#[test]
fn json_roundtrips_nested_arrays() {
    let v = roundtrip(r#"{"a": [[1, 2], [3, [4, {"b": [true, false, null]}]], []]}"#);
    let outer = v.get("a").unwrap().as_array().unwrap();
    assert_eq!(outer.len(), 3);
    assert_eq!(outer[2], Json::Arr(vec![]));
}

#[test]
fn json_number_edge_cases() {
    let v = roundtrip(r#"[0, -0.5, 1e3, 1.5e-7, 2e+8, 123456789012345, 1e308]"#);
    let a = v.as_array().unwrap();
    assert_eq!(a[0].as_f64(), Some(0.0));
    assert_eq!(a[1].as_f64(), Some(-0.5));
    assert_eq!(a[2].as_f64(), Some(1000.0));
    assert_eq!(a[3].as_f64(), Some(1.5e-7));
    assert_eq!(a[4].as_f64(), Some(2e8));
    assert_eq!(a[5].as_i64(), Some(123_456_789_012_345));
    assert_eq!(a[6].as_f64(), Some(1e308));
    // negative numbers refuse usize conversion, integers keep precision
    assert_eq!(a[1].as_usize(), None);
    assert_eq!(a[5].as_usize(), Some(123_456_789_012_345));
}

#[test]
fn json_rejects_malformed_inputs() {
    for bad in [
        "{\"a\": }",
        "[1, 2",
        "\"\\q\"",
        "tru",
        "{\"a\" 1}",
        "[1,]",
        "01x",
        "\"\\u12\"",
    ] {
        assert!(Json::parse(bad).is_err(), "{bad} should not parse");
    }
}

// ---------------------------------------------------------------------------
// runtime::manifest
// ---------------------------------------------------------------------------

const GOOD: &str = r#"{
  "config": {"d_model": 64, "n_heads": 2, "seq": 32, "d_ff": 256,
             "head_dim": 32, "dtype": "f32"},
  "input_file": "input_x.bin",
  "expected_file": "expected_out.bin",
  "tolerance": 2e-4,
  "artifacts": [
    {"name": "a1", "file": "a1.hlo.txt",
     "inputs": [{"shape": [32, 64], "dtype": "f32"}],
     "outputs": [{"shape": [32, 64], "dtype": "f32"}]}
  ],
  "pipelines": {
    "p": {"steps": [{"artifact": "a1", "in": ["x"], "out": ["out"]}],
          "output": "out"}
  }
}"#;

#[test]
fn wellformed_manifest_parses_and_validates() {
    let m = Manifest::parse(GOOD).unwrap();
    assert_eq!(m.d_model, 64);
    assert_eq!(m.input_shape, vec![32, 64]);
    assert_eq!(m.artifacts.len(), 1);
    m.validate().unwrap();
}

#[test]
fn missing_config_is_an_error() {
    let e = Manifest::parse(r#"{"artifacts": []}"#).unwrap_err();
    assert!(e.to_string().contains("config"), "{e}");
}

#[test]
fn missing_config_field_is_an_error() {
    let bad = GOOD.replace("\"seq\": 32,", "");
    let e = Manifest::parse(&bad).unwrap_err();
    assert!(e.to_string().contains("seq"), "{e}");
}

#[test]
fn artifact_missing_file_is_an_error() {
    let bad = GOOD.replace("\"file\": \"a1.hlo.txt\",", "");
    let e = Manifest::parse(&bad).unwrap_err();
    assert!(e.to_string().contains("missing file"), "{e}");
}

#[test]
fn artifact_missing_name_is_an_error() {
    let bad = GOOD.replace("\"name\": \"a1\",", "");
    let e = Manifest::parse(&bad).unwrap_err();
    assert!(e.to_string().contains("missing name"), "{e}");
}

#[test]
fn pipeline_step_missing_artifact_is_an_error() {
    let bad = GOOD.replace("\"artifact\": \"a1\",", "");
    let e = Manifest::parse(&bad).unwrap_err();
    assert!(e.to_string().contains("step missing artifact"), "{e}");
}

#[test]
fn pipeline_missing_output_is_an_error() {
    let bad = GOOD.replace("\"output\": \"out\"", "\"no_output\": 1");
    let e = Manifest::parse(&bad).unwrap_err();
    assert!(e.to_string().contains("missing output"), "{e}");
}

#[test]
fn non_json_manifest_is_an_error() {
    let e = Manifest::parse("HloModule oops").unwrap_err();
    assert!(e.to_string().contains("manifest"), "{e}");
}

#[test]
fn load_from_missing_dir_mentions_make_artifacts() {
    let e = Manifest::load(std::path::Path::new("/nonexistent")).unwrap_err();
    assert!(e.to_string().contains("make artifacts"), "{e}");
}
