//! Fabric-simulator acceptance tests: fidelity against the analytical
//! collective model on contention-free dims, determinism, and the
//! calibrated-model path through the inter-chip optimizer and the DSE.

use dfmodel::api;
use dfmodel::collective::{self, Collective, CollectiveModel};
use dfmodel::fabric::{best, build, evaluate_algos, Algo, CalibrateOpts, FabricGraph, SimConfig};
use dfmodel::graph::gpt::{gpt3_175b, gpt_layer_graph};
use dfmodel::interchip::InterChipOptions;
use dfmodel::system::interconnect::nvlink4;
use dfmodel::system::topology::{self, Dim, DimKind};
use dfmodel::system::{chip, interconnect, memory, SystemSpec};
use dfmodel::util::check::check;
use dfmodel::util::units::Bytes;

const FIVE: [Collective; 5] = [
    Collective::AllReduce,
    Collective::AllGather,
    Collective::ReduceScatter,
    Collective::AllToAll,
    Collective::P2P,
];

/// Acceptance: the ring algorithm on ring dims reproduces the α-β formula.
#[test]
fn ring_algorithm_matches_analytical_on_ring_dims() {
    for k in [4, 8, 16] {
        for bytes in [1e6, 64e6] {
            let t = topology::ring(k, &nvlink4());
            let g = FabricGraph::new(&t);
            let group: Vec<usize> = (0..k).collect();
            let s = build(&g, Algo::Ring, Collective::AllReduce, &group, bytes).unwrap();
            let sim = dfmodel::fabric::simulate(&g, &s, &SimConfig::default()).time;
            let ana = collective::time(Collective::AllReduce, Bytes::new(bytes), &t.dims[0]).raw();
            let rel = (sim - ana).abs() / ana;
            assert!(rel < 0.15, "k={k} bytes={bytes}: sim {sim} vs ana {ana} ({rel:.3})");
            // in fact the match is exact up to float noise
            assert!(rel < 1e-9, "expected exact match, got rel {rel}");
        }
    }
    // a single ring dim *inside* a torus behaves identically
    let t = topology::torus2d(4, 4, &nvlink4());
    let g = FabricGraph::new(&t);
    let col0: Vec<usize> = (0..4).collect(); // varies dim 0 only
    let s = build(&g, Algo::Ring, Collective::AllReduce, &col0, 16e6).unwrap();
    let sim = dfmodel::fabric::simulate(&g, &s, &SimConfig::default()).time;
    let ana = collective::time(Collective::AllReduce, Bytes::new(16e6), &t.dims[0]).raw();
    assert!((sim - ana).abs() / ana < 1e-9);
}

/// Satellite: on contention-free fully-connected/switch dims, the best
/// simulated algorithm lands within 15% of `collective::time` for every
/// collective with a scatter-style optimal schedule. (Broadcast is excluded
/// by design: the closed form assumes hardware multicast.)
#[test]
fn fabric_matches_analytical_on_fc_and_switch_dims() {
    check("fabric-fc-switch-15pct", 24, |rng| {
        let kind =
            if rng.below(2) == 0 { DimKind::FullyConnected } else { DimKind::Switch };
        let k = [2usize, 4, 8, 16][rng.below(4)];
        let bytes = rng.uniform(8e6, 128e6);
        let coll = FIVE[rng.below(FIVE.len())];
        let t = topology::Topology::new("prop", vec![Dim::new(kind, k, &nvlink4())]);
        let g = FabricGraph::new(&t);
        let group: Vec<usize> = (0..k).collect();
        let b = best(&g, &group, coll, bytes, &SimConfig::default()).expect("feasible");
        let ana = collective::time(coll, Bytes::new(bytes), &t.dims[0]).raw();
        let rel = (b.time - ana).abs() / ana;
        assert!(
            rel < 0.15,
            "{kind:?}({k}) {coll:?} S={bytes:.2e}: best {:?} sim {} vs ana {ana} ({rel:.3})",
            b.algo,
            b.time
        );
    });
}

/// The hierarchical schedule is the simulation twin of `time_hier`.
#[test]
fn hier_schedule_matches_time_hier_on_torus() {
    let t = topology::torus2d(4, 4, &nvlink4());
    let g = FabricGraph::new(&t);
    let group: Vec<usize> = (0..16).collect();
    let dims: Vec<&Dim> = t.dims.iter().collect();
    for coll in [Collective::AllReduce, Collective::AllGather, Collective::ReduceScatter] {
        for bytes in [1e6, 64e6] {
            let s = build(&g, Algo::Hier, coll, &group, bytes).unwrap();
            let sim = dfmodel::fabric::simulate(&g, &s, &SimConfig::default()).time;
            let ana = collective::time_hier(coll, Bytes::new(bytes), &dims).raw();
            let rel = (sim - ana).abs() / ana;
            assert!(rel < 0.02, "{coll:?} S={bytes:.0e}: sim {sim} ana {ana} ({rel:.3})");
        }
    }
}

/// Same config → bit-identical results, across the whole selection sweep.
#[test]
fn evaluation_sweep_is_deterministic() {
    let t = topology::torus2d(4, 4, &nvlink4());
    let g = FabricGraph::new(&t);
    let group: Vec<usize> = (0..16).collect();
    let cfg = SimConfig::default();
    let a = evaluate_algos(&g, &group, Collective::AllReduce, 16e6, &cfg);
    let b = evaluate_algos(&g, &group, Collective::AllReduce, 16e6, &cfg);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.algo, y.algo);
        assert_eq!(x.time, y.time);
        assert_eq!(x.events, y.events);
    }
}

/// The DGX-1 hybrid cube-mesh is materially slower than the analytical
/// fully-connected shortcut — the fidelity gap the `fabric` figure reports.
#[test]
fn dgx1_cube_mesh_gap_is_quantified() {
    let t = topology::dgx1(1, &nvlink4());
    let g = FabricGraph::new(&t);
    let group: Vec<usize> = (0..8).collect();
    let b = best(&g, &group, Collective::AllReduce, 64e6, &SimConfig::default()).unwrap();
    let fc = Dim::new(DimKind::FullyConnected, 8, &nvlink4());
    let ana = collective::time(Collective::AllReduce, Bytes::new(64e6), &fc).raw();
    let gap = b.time / ana;
    assert!(gap > 2.0 && gap < 10.0, "cube-mesh/FC gap {gap}");
}

/// CollectiveModel::Calibrated threads through the facade's inter-chip
/// pass: the optimizer runs end-to-end on simulation-calibrated costs and
/// the result stays in the same regime as the analytical one.
#[test]
fn calibrated_model_threads_through_interchip_optimize() {
    let link = interconnect::pcie4();
    let sys = SystemSpec::new(
        chip::sn10(),
        memory::ddr4(),
        link.clone(),
        topology::ring(8, &link),
    );
    let cal_sys = api::calibrate(&sys, &CalibrateOpts::default());
    match &cal_sys.collective_model {
        CollectiveModel::Calibrated(c) => assert!(!c.is_empty()),
        m => panic!("expected calibrated model, got {m:?}"),
    }
    let g = gpt_layer_graph(&gpt3_175b(), 1.0);
    let opts = InterChipOptions { force_degrees: Some((8, 1, 1)), ..Default::default() };
    let ana = api::map_graph(&g, &sys, &opts).expect("analytical mapping");
    let cal = api::map_graph(&g, &cal_sys, &opts).expect("calibrated mapping");
    assert!(cal.t_cri.is_finite() && cal.t_cri.raw() > 0.0);
    let ratio = cal.t_cri / ana.t_cri;
    assert!((0.2..5.0).contains(&ratio), "calibrated/analytical t_cri ratio {ratio}");
}

/// The calibrated path also reaches the DSE design-point entry, both via
/// the typed wrappers and via a calibrated-fabric scenario.
#[test]
fn calibrated_dse_point_evaluates() {
    use dfmodel::api::{Scenario, SystemCfg};
    use dfmodel::dse::Workload;
    let link = interconnect::nvlink4();
    let sys = SystemSpec::new(
        chip::h100(),
        memory::hbm3(),
        link.clone(),
        topology::torus2d(32, 32, &link),
    );
    let ana = api::evaluate_design(Workload::Llm, &sys).expect("analytical point");
    let cal = api::evaluate_design_calibrated(Workload::Llm, &sys, &CalibrateOpts::default())
        .expect("calibrated point");
    assert!(cal.utilization > 0.0 && cal.utilization <= 1.0);
    let ratio = cal.utilization / ana.utilization;
    assert!((0.2..5.0).contains(&ratio), "calibrated/analytical utilization ratio {ratio}");
    // the scenario path prices with the same calibrated model
    let scenario = Scenario::llm("gpt3-1t")
        .batch(2048.0)
        .on(SystemCfg::new("h100", "hbm3", "nvlink4").torus2d(32, 32))
        .calibrated_fabric();
    let report = scenario.evaluate().expect("calibrated scenario");
    assert_eq!(report.utilization(), Some(cal.utilization));
}
