//! Integration tests for the cluster subsystem: arrival-process statistics,
//! end-to-end determinism, the simulator-vs-analytical TPOT regression, KV
//! admission control, and the SLO-aware capacity planner.

use dfmodel::cluster::engine::{simulate, ReplicaConfig, Slo};
use dfmodel::cluster::planner::{self, PlanTarget, PlanTraffic};
use dfmodel::cluster::workload::{Request, TraceSpec};
use dfmodel::graph::llama::{llama3_70b, llama3_8b};
use dfmodel::serving::{evaluate, sn40l_x16, ServingPoint};

fn slo() -> Slo {
    Slo { ttft: 1.0, tpot: 0.02 }
}

#[test]
fn poisson_mean_interarrival_matches_rate() {
    // statistical sanity of util::prng::exp + the Poisson generator: for a
    // fixed seed the empirical mean inter-arrival must sit within 5% of
    // 1/λ (the estimator's σ at n=2000 is ~2.2% of the mean).
    let rate = 5.0;
    let trace = TraceSpec::poisson(42, rate, 2000).generate();
    let mean = trace.last().unwrap().arrival / trace.len() as f64;
    assert!(
        (mean * rate - 1.0).abs() < 0.05,
        "mean inter-arrival {mean:.4} s, expected {:.4} s",
        1.0 / rate
    );
    for w in trace.windows(2) {
        assert!(w[1].arrival > w[0].arrival, "arrivals must be strictly increasing");
    }
}

#[test]
fn same_seed_same_event_trace() {
    // determinism end to end: identical traces in, identical per-request
    // metrics, event counts, and step counts out.
    let cfg = ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1);
    let spec = TraceSpec::poisson(3, 8.0, 300);
    assert_eq!(spec.generate(), spec.generate());
    let a = simulate(&cfg, 2, &spec.generate(), &slo()).unwrap();
    let b = simulate(&cfg, 2, &spec.generate(), &slo()).unwrap();
    assert_eq!(a.per_request, b.per_request);
    assert_eq!(a.events, b.events);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.makespan, b.makespan);
    // a different seed must actually change the outcome
    let c = simulate(&cfg, 2, &TraceSpec::poisson(4, 8.0, 300).generate(), &slo()).unwrap();
    assert_ne!(a.per_request, c.per_request);
}

#[test]
fn simulator_reproduces_analytical_tpot_at_batch_1() {
    // acceptance criterion: at batch=1, single replica, steady state, the
    // DES must reproduce the §VIII-A analytical TPOT within 10%. Requests
    // are spaced far apart so at most one is ever in flight; the analytical
    // reference uses the midpoint decode context.
    let model = llama3_8b();
    let sys = sn40l_x16();
    let cfg = ReplicaConfig::new(model, sys.clone(), 16, 1);
    let (prompt, output) = (1024usize, 129usize);
    let requests: Vec<Request> = (0..4)
        .map(|i| Request { id: i, arrival: i as f64 * 1000.0, prompt, output })
        .collect();
    let r = simulate(&cfg, 1, &requests, &slo()).unwrap();
    assert_eq!(r.n_completed, 4);
    let mid = ServingPoint {
        tp: 16,
        pp: 1,
        batch: 1.0,
        prompt_len: 1.0,
        context: prompt as f64 + output as f64 / 2.0,
    };
    let ana = evaluate(&model, &sys, &mid).unwrap().tpot;
    assert!(
        (r.tpot.mean / ana - 1.0).abs() < 0.10,
        "sim TPOT {:.6e} vs analytical {ana:.6e}",
        r.tpot.mean
    );
    // an unqueued request's TTFT is exactly one analytical prefill pass
    let pre = ServingPoint {
        tp: 16,
        pp: 1,
        batch: 1.0,
        prompt_len: prompt as f64,
        context: prompt as f64,
    };
    let ana_ttft = evaluate(&model, &sys, &pre).unwrap().ttft;
    assert!(
        (r.ttft.mean / ana_ttft - 1.0).abs() < 0.05,
        "sim TTFT {:.6e} vs analytical {ana_ttft:.6e}",
        r.ttft.mean
    );
}

#[test]
fn kv_capacity_bounds_admission() {
    // shrink device memory so only ~2 requests' KV reservations fit: the
    // engine must queue the rest rather than oversubscribe, and still
    // finish everything.
    let model = llama3_8b();
    let mut sys = sn40l_x16();
    let kv_need = 1088.0 * model.kv_bytes_per_token();
    sys.mem_cap = (model.weight_bytes() + 2.2 * kv_need / 0.9) / 16.0;
    let mut cfg = ReplicaConfig::new(model, sys, 16, 1);
    cfg.max_batch = 16;
    let requests: Vec<Request> = (0..8)
        .map(|i| Request { id: i, arrival: 0.001 * i as f64, prompt: 1024, output: 64 })
        .collect();
    let r = simulate(&cfg, 1, &requests, &slo()).unwrap();
    assert_eq!(r.n_completed, 8, "queued requests must still complete");
    assert!(r.kv_peak_frac <= 1.0 + 1e-9, "admission oversubscribed: {}", r.kv_peak_frac);
    assert!(r.kv_peak_frac > 0.8, "the budget should be nearly saturated");
    assert!(r.queue.p99 > 0.0, "KV pressure should force queueing");
}

#[test]
fn overload_degrades_goodput_and_tail_latency() {
    let cfg = ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1);
    let light = simulate(&cfg, 1, &TraceSpec::poisson(5, 2.0, 150).generate(), &slo()).unwrap();
    let heavy = simulate(&cfg, 1, &TraceSpec::poisson(5, 60.0, 150).generate(), &slo()).unwrap();
    assert!(light.slo_attainment > 0.9, "light-load attainment {}", light.slo_attainment);
    assert!(
        heavy.slo_attainment < 0.5,
        "3x-over-capacity attainment {}",
        heavy.slo_attainment
    );
    assert!(heavy.ttft.p99 > light.ttft.p99);
    assert!(heavy.goodput_rps < heavy.throughput_rps);
}

#[test]
fn planner_finds_concrete_llama70b_fleet() {
    // acceptance criterion: `plan --qps 2 --slo-ttft 2 --slo-tpot 0.05`
    // must return a concrete fleet (chip, TP×PP, replicas, $/hr) for
    // Llama3-70B.
    let target =
        PlanTarget { qps: 2.0, slo: Slo { ttft: 2.0, tpot: 0.05 }, attainment: 0.9 };
    let traffic = PlanTraffic { n_requests: 200, ..Default::default() };
    let res = planner::plan(&llama3_70b(), &target, &traffic);
    let best = res.best.expect("some fleet must meet 2 rps at these SLOs");
    let plan = &res.candidates[best];
    assert!(plan.meets_target);
    assert!(plan.replicas >= 1);
    assert_eq!(plan.chips_total, plan.group * plan.replicas);
    assert_eq!(plan.tp * plan.pp, plan.group);
    assert!(plan.usd_per_hour > 0.0 && plan.capex_usd > 0.0);
    assert!(plan.report.slo_attainment >= target.attainment);
    // the winner is the cheapest: everything ranked above it failed
    for c in &res.candidates[..best] {
        assert!(!c.meets_target, "cheaper candidate {} also meets the target", c.platform);
    }
    // the sweep covered multiple platforms and split shapes
    let platforms: std::collections::BTreeSet<&str> =
        res.candidates.iter().map(|c| c.platform.as_str()).collect();
    assert!(platforms.len() >= 3, "expected a multi-platform sweep, got {platforms:?}");
}

#[test]
fn planner_reports_failure_on_impossible_slo() {
    // a 1 µs TPOT bound is physically unreachable for every platform
    let target =
        PlanTarget { qps: 1.0, slo: Slo { ttft: 1e-6, tpot: 1e-6 }, attainment: 0.9 };
    let traffic = PlanTraffic { n_requests: 40, ..Default::default() };
    let res = planner::plan(&llama3_70b(), &target, &traffic);
    assert!(res.best.is_none());
    assert!(!res.candidates.is_empty(), "candidates are still reported for inspection");
}
