//! Integration tests across the optimizer stack: inter-chip × intra-chip ×
//! pipeline composition, invariants under randomized workloads/systems, and
//! failure injection (infeasible capacities, degenerate topologies).

use dfmodel::api;
use dfmodel::assign::Assignment;
use dfmodel::graph::{gpt, GraphBuilder, KernelKind};
use dfmodel::interchip::{self, InterChipOptions};
use dfmodel::intrachip::IntraChipOptions;
use dfmodel::system::{chip, interconnect, memory, topology, SystemSpec};
use dfmodel::util::check::check;
use dfmodel::util::prng::Rng;

fn random_chain_graph(rng: &mut Rng, n: usize) -> dfmodel::graph::DataflowGraph {
    let mut b = GraphBuilder::new("rand-chain");
    let mut prev = None;
    for i in 0..n {
        let kind = match rng.below(4) {
            0 => KernelKind::Gemm {
                b: 1.0,
                m: rng.uniform(64.0, 4096.0).round(),
                k: rng.uniform(64.0, 4096.0).round(),
                n: rng.uniform(64.0, 4096.0).round(),
            },
            1 => KernelKind::Elementwise {
                elems: rng.uniform(1e4, 1e7).round(),
                flop_per_elem: 2.0,
            },
            2 => KernelKind::Softmax {
                rows: rng.uniform(64.0, 2048.0).round(),
                cols: rng.uniform(64.0, 2048.0).round(),
            },
            _ => KernelKind::LayerNorm {
                rows: rng.uniform(64.0, 2048.0).round(),
                cols: rng.uniform(64.0, 2048.0).round(),
            },
        };
        let w = if matches!(kind, KernelKind::Gemm { .. }) {
            rng.uniform(1e5, 1e8)
        } else {
            0.0
        };
        let k = b.kernel(&format!("k{i}"), kind, w);
        if let Some(p) = prev {
            b.tensor(&format!("t{i}"), p, k, rng.uniform(1e4, 1e7));
        }
        prev = Some(k);
    }
    b.build()
}

fn random_system(rng: &mut Rng) -> SystemSpec {
    let link = if rng.below(2) == 0 { interconnect::pcie4() } else { interconnect::nvlink4() };
    let mem = if rng.below(2) == 0 { memory::ddr4() } else { memory::hbm3() };
    let c = match rng.below(4) {
        0 => chip::h100(),
        1 => chip::tpu_v4(),
        2 => chip::sn30(),
        _ => chip::sn10(),
    };
    let topo = match rng.below(3) {
        0 => topology::ring(8, &link),
        1 => topology::torus2d(4, 2, &link),
        _ => topology::torus2d(4, 4, &link),
    };
    SystemSpec::new(c, mem, link, topo)
}

#[test]
fn interchip_mapping_invariants_on_random_instances() {
    check("interchip-invariants", 30, |rng| {
        let n = 3 + rng.below(8);
        let g = random_chain_graph(rng, n);
        let sys = random_system(rng);
        let Some(m) = api::map_graph(&g, &sys, &InterChipOptions::default()) else {
            return; // infeasible is a legal outcome
        };
        // degrees use all chips
        assert_eq!(m.plan.tp * m.plan.pp * m.plan.dp, sys.n_chips());
        // stages are precedence-feasible and contiguous over topo order
        let asg = Assignment::new(m.stage_of.clone(), m.stages.len());
        assert!(asg.respects_precedence(&g), "stage precedence violated");
        // objective equals the max stage critical time
        let max_stage = m.stages.iter().map(|s| s.t_cri().raw()).fold(0.0f64, f64::max);
        assert!((m.t_cri.raw() - max_stage).abs() <= 1e-12 * max_stage.max(1.0));
        // latency vectors are non-negative and finite
        assert!(m.vectors.h_c.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(m.vectors.h_n.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(m.vectors.h_m.iter().all(|v| v.is_finite() && *v >= 0.0));
    });
}

#[test]
fn intrachip_mapping_invariants_on_random_instances() {
    check("intrachip-invariants", 30, |rng| {
        let n = 3 + rng.below(10);
        let g = random_chain_graph(rng, n);
        let c = if rng.below(2) == 0 { chip::sn10() } else { chip::sn30() };
        let mem = memory::ddr4();
        let Some(m) = api::map_chip(&g, &c, &mem, &IntraChipOptions::default()) else {
            return;
        };
        // partitions cover all kernels, precedence-feasible
        assert!(m.assignment.respects_precedence(&g));
        assert_eq!(m.assignment.part.len(), g.n_kernels());
        // total time is the sum of partition criticals
        let sum: f64 = m.partitions.iter().map(|p| p.t_cri()).sum();
        assert!((m.total_time - sum).abs() <= 1e-12 * sum.max(1.0));
        // SRAM constraint holds in every partition
        for p in &m.partitions {
            assert!(p.sram_used <= c.sram_bytes.raw() * (1.0 + 1e-9), "SRAM violated");
        }
        // fusing never increases DRAM traffic or total time vs kernel-by-kernel
        let kbk = api::map_chip(
            &g,
            &c,
            &mem,
            &IntraChipOptions { force_kernel_by_kernel: true, ..Default::default() },
        )
        .unwrap();
        assert!(m.total_dram_traffic() <= kbk.total_dram_traffic() + 1e-9);
        assert!(m.total_time <= kbk.total_time * (1.0 + 1e-9));
    });
}

#[test]
fn sharded_graph_conserves_totals() {
    check("shard-conservation", 20, |rng| {
        let n = 4 + rng.below(6);
        let g = random_chain_graph(rng, n);
        let sys = random_system(rng);
        let plans = interchip::enumerate_plans(&sys.topology);
        let plan = rng.choice(&plans).clone();
        let (schemes, _) = interchip::optimizer::select_sharding(
            &g,
            &sys,
            &plan,
            &InterChipOptions::default(),
        );
        let (sharded, net) = interchip::shard_graph(&g, &sys, &plan, &schemes);
        // per-chip totals never exceed the unsharded totals
        assert!(sharded.total_flops() <= g.total_flops() * (1.0 + 1e-9));
        assert!(sharded.total_weight_bytes() <= g.total_weight_bytes() * (1.0 + 1e-9));
        // sharded totals × tp at least cover the original work
        let tp = plan.tp as f64;
        assert!(sharded.total_flops() * tp >= g.total_flops() * (1.0 - 1e-9));
        assert_eq!(net.len(), g.n_kernels());
        assert!(net.iter().all(|v| v.is_finite() && *v >= 0.0));
    });
}

#[test]
fn pipeline_monotone_in_link_bandwidth() {
    // a strictly faster interconnect can never lower modeled utilization
    let cfg = gpt::gpt3_175b();
    let mk = |link: dfmodel::system::LinkTech| {
        SystemSpec::new(
            chip::sn10(),
            memory::ddr4(),
            link.clone(),
            topology::ring(8, &link),
        )
    };
    let slow = dfmodel::pipeline::llm_training(&cfg, &mk(interconnect::pcie4()), 64.0).unwrap();
    let fast = dfmodel::pipeline::llm_training(&cfg, &mk(interconnect::nvlink4()), 64.0).unwrap();
    assert!(fast.utilization >= slow.utilization * (1.0 - 1e-9));
}

#[test]
fn pipeline_monotone_in_memory_bandwidth() {
    let cfg = gpt::gpt3_175b();
    let link = interconnect::pcie4();
    let mut kbk_chip = chip::sn10();
    kbk_chip.execution = dfmodel::system::ExecutionModel::KernelByKernel;
    let mk = |bw: f64| {
        let mut mem = memory::ddr4();
        mem.bandwidth = dfmodel::util::units::BytesPerSec::new(bw);
        SystemSpec::new(kbk_chip.clone(), mem, link.clone(), topology::ring(8, &link))
    };
    let slow = dfmodel::pipeline::llm_training(&cfg, &mk(100e9), 64.0).unwrap();
    let fast = dfmodel::pipeline::llm_training(&cfg, &mk(600e9), 64.0).unwrap();
    assert!(fast.utilization >= slow.utilization * (1.0 - 1e-9));
}

#[test]
fn failure_injection_zero_capacity_memory() {
    let cfg = gpt::gpt3_1t();
    let link = interconnect::pcie4();
    let mut mem = memory::ddr4();
    mem.capacity = dfmodel::util::units::Bytes::new(1.0); // 1 byte
    let sys = SystemSpec::new(chip::sn10(), mem, link.clone(), topology::ring(8, &link));
    assert!(dfmodel::pipeline::llm_training(&cfg, &sys, 64.0).is_none());
}

#[test]
fn failure_injection_single_chip_system() {
    // degenerate 1-chip topology: no parallelism, still a valid mapping for
    // a small model
    let cfg = gpt::GptConfig {
        layers: 2,
        d_model: 1024.0,
        n_heads: 8.0,
        seq: 512.0,
        d_ff: 4096.0,
        vocab: 1000.0,
        dtype_bytes: 2.0,
    };
    let link = interconnect::pcie4();
    let sys =
        SystemSpec::new(chip::sn10(), memory::ddr4(), link.clone(), topology::ring(1, &link));
    let r = dfmodel::pipeline::llm_training(&cfg, &sys, 8.0).expect("1-chip feasible");
    assert_eq!((r.tp, r.pp, r.dp), (1, 1, 1));
    assert!(r.utilization > 0.0);
}

#[test]
fn forced_degrees_cover_the_torus_plans() {
    // every enumerated plan of a 4x2 torus must be reachable via forcing
    let g = gpt::gpt_coarse_graph(&gpt::gpt3_175b(), 1.0);
    let link = interconnect::pcie4();
    let sys = SystemSpec::new(
        chip::sn10(),
        memory::ddr4(),
        link.clone(),
        topology::torus2d(4, 2, &link),
    );
    for plan in interchip::enumerate_plans(&sys.topology) {
        if plan.pp > g.n_kernels() {
            continue;
        }
        let m = api::map_graph(
            &g,
            &sys,
            &InterChipOptions {
                force_degrees: Some((plan.tp, plan.pp, plan.dp)),
                ..Default::default()
            },
        );
        if let Some(m) = m {
            assert_eq!((m.plan.tp, m.plan.pp, m.plan.dp), (plan.tp, plan.pp, plan.dp));
        }
    }
}

#[test]
fn hpl_feasible_on_sampled_dse_systems() {
    // spot-check a handful of the 80 systems rather than the full sweep
    let systems = dfmodel::dse::dse_systems_1024();
    let mut rng = Rng::new(42);
    let mut feasible = 0;
    let mut total = 0;
    for _ in 0..6 {
        let sys = rng.choice(systems);
        total += 1;
        if api::evaluate_design(dfmodel::dse::Workload::Hpl, sys).is_some() {
            feasible += 1;
        }
    }
    assert!(feasible * 2 >= total, "too many infeasible HPL points: {feasible}/{total}");
}
