//! Observability integration tests: traced scenario evaluation end to end,
//! worker-count-independent span capture across the explorer's parallel
//! map, Chrome trace-event export invariants, per-axis explorer counters,
//! and disabled-path bit-parity of reports.

use dfmodel::api::{ExploreOptions, Scenario};
use dfmodel::dse::Workload;
use dfmodel::explore::{explore, ChipCfg, ExploreSettings, MemCfg, SearchSpace, WorkloadSpec};
use dfmodel::graph::gpt::GptConfig;
use dfmodel::util::json::Json;

fn tiny_gpt() -> GptConfig {
    GptConfig {
        layers: 8,
        d_model: 1024.0,
        n_heads: 8.0,
        seq: 512.0,
        d_ff: 4096.0,
        vocab: 32000.0,
        dtype_bytes: 2.0,
    }
}

/// 2 chips × 2 mems × 2 links × 2 topologies = 16 candidates at 8 chips.
fn small_space() -> SearchSpace {
    SearchSpace {
        workload: WorkloadSpec {
            kind: Workload::Llm,
            gpt: Some(tiny_gpt()),
            batch: Some(32.0),
            state_bytes_per_weight_byte: None,
        },
        chips: vec![ChipCfg::named("sn30"), ChipCfg::named("h100")],
        mems: vec![
            MemCfg::named("hbm3"),
            MemCfg { name: "ddr4".into(), bandwidth_gbs: Some(25.0), capacity_gb: None },
        ],
        links: vec!["nvlink4".into(), "pcie4".into()],
        topologies: vec!["torus2d".into(), "ring".into()],
        chip_counts: vec![8],
        batches: vec![None],
    }
}

/// The recorded span tree and counters must be a function of the work, not
/// of how many workers the parallel map used.
#[test]
fn capture_structure_is_independent_of_worker_count() {
    let space = small_space();
    let run = |workers: usize| {
        let sess = dfmodel::obs::start_capture();
        let out = explore(
            &space,
            &ExploreSettings { prune: false, workers: Some(workers), ..Default::default() },
        )
        .unwrap();
        let cap = dfmodel::obs::finish_capture(sess);
        (out, cap)
    };
    let (out1, cap1) = run(1);
    let (out4, cap4) = run(4);
    assert_eq!(out1.frontier, out4.frontier);
    assert_eq!(
        cap1.structure(),
        cap4.structure(),
        "span tree shape must not depend on worker count"
    );
    assert_eq!(cap1.n_spans(), cap4.n_spans());
    for c in ["explore.evaluated", "explore.cache_hits", "explore.pruned"] {
        assert_eq!(cap1.counter(c), cap4.counter(c), "counter {c} diverged");
    }
    assert_eq!(cap1.counter("explore.evaluated"), Some(out1.evaluated as u64));
}

/// Per-axis rows partition the enumerated candidates on every axis.
#[test]
fn axis_stats_partition_the_candidates() {
    let out = explore(&small_space(), &ExploreSettings::default()).unwrap();
    assert!(!out.axes.is_empty());
    for axis in ["chip", "mem", "link", "topo"] {
        let total: usize = out
            .axes
            .iter()
            .filter(|a| a.axis == axis)
            .map(|a| a.evaluated + a.cache_hits + a.pruned + a.skipped_budget)
            .sum();
        assert_eq!(total, out.candidates, "axis '{axis}' rows must cover every candidate");
    }
    // deterministic ordering: axis rank (chip, mem, link, topo) then value
    let ranks: Vec<usize> = out
        .axes
        .iter()
        .map(|a| ["chip", "mem", "link", "topo"].iter().position(|&x| x == a.axis).unwrap())
        .collect();
    let mut sorted = ranks.clone();
    sorted.sort_unstable();
    assert_eq!(ranks, sorted);
}

/// A traced explore scenario reports the axis rows and the metrics JSON.
#[test]
fn traced_explore_scenario_reports_axes_and_stats() {
    let opts = ExploreOptions {
        chips: vec![ChipCfg::named("sn30"), ChipCfg::named("h100")],
        mems: vec![MemCfg::named("hbm3")],
        links: vec!["pcie4".into()],
        topologies: vec!["ring".into(), "torus2d".into()],
        chip_counts: vec![8],
        batches: vec![None],
        prune: true,
        budget: None,
        top: 4,
    };
    let s = Scenario::llm_custom(tiny_gpt()).batch(16.0).explore(opts).traced();
    let r = s.evaluate().unwrap();
    let e = r.explore.as_ref().expect("explore section");
    assert!(!e.axes.is_empty());
    let text = r.render();
    assert!(text.contains("axis chip"), "per-axis rows missing from render:\n{text}");
    let json = r.to_json();
    assert!(json.get("explore").unwrap().get("axes").is_some());
    let stats = json.get("stats").expect("traced report emits stats");
    assert!(stats.get("explore.evaluated").is_some(), "{}", stats.pretty());
    // the human rendering carries the span tree footer
    assert!(text.contains("scenario.evaluate"), "span tree missing from render:\n{text}");
}

/// Chrome trace export: a JSON array of balanced B/E events that survives
/// a parse round-trip.
#[test]
fn chrome_trace_events_are_balanced_and_parse_back() {
    let s = Scenario::llm("gpt3-175b").traced();
    let r = s.evaluate().unwrap();
    let cap = r.stats.as_ref().expect("traced run fills stats");
    let trace = dfmodel::obs::chrome_trace(cap);
    let back = Json::parse(&trace.pretty()).expect("trace JSON parses");
    let Json::Arr(events) = back else { panic!("trace must be a JSON array") };
    assert!(!events.is_empty());
    let ph = |e: &Json| e.get("ph").and_then(|v| v.as_str()).unwrap().to_string();
    let begins = events.iter().filter(|e| ph(e) == "B").count();
    let ends = events.iter().filter(|e| ph(e) == "E").count();
    assert_eq!(begins, ends, "unbalanced B/E events");
    assert!(begins > 0);
    for e in &events {
        assert!(e.get("name").is_some() && e.get("ts").is_some() && e.get("pid").is_some());
    }
}

/// An untraced report must not carry (or emit) any instrumentation: the
/// JSON has no `stats` key and equals a second untraced run's bit for bit.
#[test]
fn untraced_reports_carry_no_stats() {
    let s = Scenario::llama("8b").serving_split(16, 1);
    let a = s.evaluate().unwrap();
    assert!(a.stats.is_none());
    assert!(a.to_json().get("stats").is_none());
    let b = s.evaluate().unwrap();
    assert_eq!(a, b);
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());
}
