//! Facade integration tests: the committed scenario files drive the same
//! entry point as the CLI, scenarios round-trip through JSON, and the
//! report JSON exposes the stable keys the CI smoke test checks.

use std::path::Path;

use dfmodel::api::{Goal, Scenario};

fn scenario_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

#[test]
fn committed_llm_dgx_scenario_reproduces_a_paper_design_point() {
    let s = Scenario::load(&scenario_dir().join("llm_dgx.json")).expect("load scenario");
    assert_eq!(s.goal, Goal::Map);
    let r = s.evaluate().expect("feasible");
    let (tp, pp, dp) = r.degrees().unwrap();
    assert_eq!(tp * pp * dp, 1024, "the DGX-scale point spans 1024 chips");
    let u = r.utilization().unwrap();
    assert!(u > 0.0 && u <= 1.0, "utilization {u}");
    // the CI smoke run pipes this through `jq -e '.perf.utilization'`
    let json = r.to_json();
    assert!(json.get("perf").unwrap().get("utilization").unwrap().as_f64().is_some());
    assert!(json.get("mapping").unwrap().get("tp").is_some());
}

#[test]
fn committed_serve_scenario_evaluates() {
    let s = Scenario::load(&scenario_dir().join("serve_sn40l.json")).expect("load scenario");
    assert_eq!(s.goal, Goal::Serve);
    let r = s.evaluate().expect("feasible");
    let v = r.serving.as_ref().expect("serve goal fills serving");
    assert!(v.decode_tps > 0.0 && v.ttft > 0.0);
}

#[test]
fn scenario_files_roundtrip_through_json() {
    for name in ["llm_dgx.json", "serve_sn40l.json"] {
        let s = Scenario::load(&scenario_dir().join(name)).unwrap();
        let re = Scenario::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(s, re, "{name} must round-trip");
    }
}

#[test]
fn report_renders_human_text() {
    let r = Scenario::llama("8b").evaluate().unwrap();
    let text = r.render();
    assert!(text.contains("TTFT") && text.contains("decode"), "{text}");
}
