//! Property tests on the analytical-model invariants: collective cost
//! models, sharding algebra, serving metrics, and the JSON substrate.

use dfmodel::collective::{time, time_hier, Collective};
use dfmodel::graph::llama::llama3_8b;
use dfmodel::serving::{evaluate, sn40l_x16, ServingPoint};
use dfmodel::sharding::{conversion_op, conversion_time, Layout};
use dfmodel::system::interconnect::{nvlink4, pcie4};
use dfmodel::system::topology::{Dim, DimKind};
use dfmodel::util::check::check;
use dfmodel::util::json::Json;
use dfmodel::util::units::Bytes;

const COLLS: [Collective; 6] = [
    Collective::AllReduce,
    Collective::AllGather,
    Collective::ReduceScatter,
    Collective::Broadcast,
    Collective::AllToAll,
    Collective::P2P,
];

const KINDS: [DimKind; 3] = [DimKind::Ring, DimKind::FullyConnected, DimKind::Switch];

#[test]
fn collective_time_monotone_in_bytes() {
    check("coll-monotone-bytes", 100, |rng| {
        let kind = *rng.choice(&KINDS);
        let k = 2 + rng.below(63);
        let dim = Dim::new(kind, k, &nvlink4());
        let coll = *rng.choice(&COLLS);
        let s1 = rng.uniform(1e3, 1e9);
        let s2 = s1 * rng.uniform(1.0, 10.0);
        let (t1, t2) =
            (time(coll, Bytes::new(s1), &dim).raw(), time(coll, Bytes::new(s2), &dim).raw());
        assert!(t2 >= t1 - 1e-15, "{coll:?} {kind:?} k={k}: {t1} vs {t2}");
    });
}

#[test]
fn collective_time_nonnegative_free_singletons_and_ar_dominates_ag() {
    // three invariants across every (collective, dim kind): times are
    // non-negative and finite, a singleton dim is free, and all-reduce
    // costs at least an all-gather of the same buffer (it moves strictly
    // more data: reduce-scatter + all-gather).
    check("coll-nonneg-ar-ge-ag", 120, |rng| {
        let kind = *rng.choice(&KINDS);
        let coll = *rng.choice(&COLLS);
        let s = rng.uniform(1.0, 1e10);
        let single = Dim::new(kind, 1, &nvlink4());
        assert_eq!(time(coll, Bytes::new(s), &single).raw(), 0.0, "{coll:?} {kind:?} singleton not free");
        let k = 2 + rng.below(127);
        let dim = Dim::new(kind, k, &nvlink4());
        let t = time(coll, Bytes::new(s), &dim).raw();
        assert!(t.is_finite() && t >= 0.0, "{coll:?} {kind:?} k={k}: t={t}");
        let ar = time(Collective::AllReduce, Bytes::new(s), &dim).raw();
        let ag = time(Collective::AllGather, Bytes::new(s), &dim).raw();
        assert!(ar >= ag - 1e-15, "{kind:?} k={k}: all-reduce {ar} < all-gather {ag}");
    });
}

#[test]
fn collective_time_monotone_in_bandwidth() {
    check("coll-monotone-bw", 100, |rng| {
        let kind = *rng.choice(&KINDS);
        let k = 2 + rng.below(63);
        let fast = Dim::new(kind, k, &nvlink4());
        let slow = Dim::new(kind, k, &pcie4());
        let coll = *rng.choice(&COLLS);
        let s = rng.uniform(1e3, 1e9);
        assert!(time(coll, Bytes::new(s), &fast).raw() <= time(coll, Bytes::new(s), &slow).raw() + 1e-15);
    });
}

#[test]
fn allreduce_equals_rs_plus_ag_on_every_kind() {
    // the decomposition identity the Megatron validation relies on
    check("ar-rs-ag-identity", 60, |rng| {
        let kind = *rng.choice(&KINDS);
        let k = 2 + rng.below(63);
        let dim = Dim::new(kind, k, &nvlink4());
        let s = rng.uniform(1e4, 1e9);
        let ar = time(Collective::AllReduce, Bytes::new(s), &dim).raw();
        let rs_ag = time(Collective::ReduceScatter, Bytes::new(s), &dim).raw()
            + time(Collective::AllGather, Bytes::new(s), &dim).raw();
        assert!(
            (ar - rs_ag).abs() <= 1e-9 * ar.max(1e-12),
            "{kind:?} k={k}: ar {ar} vs rs+ag {rs_ag}"
        );
    });
}

#[test]
fn hierarchical_collectives_nonnegative_and_finite() {
    check("hier-sane", 80, |rng| {
        let d1 = Dim::new(*rng.choice(&KINDS), 1 + rng.below(32), &nvlink4());
        let d2 = Dim::new(*rng.choice(&KINDS), 1 + rng.below(32), &pcie4());
        let coll = *rng.choice(&COLLS);
        let s = rng.uniform(0.0, 1e9);
        let t = time_hier(coll, Bytes::new(s), &[&d1, &d2]).raw();
        assert!(t.is_finite() && t >= 0.0);
        // zero payload is free
        assert_eq!(time_hier(coll, Bytes::new(0.0), &[&d1, &d2]).raw(), 0.0);
    });
}

#[test]
fn hierarchical_time_monotone_in_payload_across_all_dim_kinds() {
    // the fabric calibration interpolates over payload, so the analytical
    // baseline it rescales must itself be monotone in bytes for every
    // hierarchy of dim kinds
    check("hier-monotone-bytes", 120, |rng| {
        let d1 = Dim::new(*rng.choice(&KINDS), 2 + rng.below(31), &nvlink4());
        let d2 = Dim::new(*rng.choice(&KINDS), 1 + rng.below(32), &pcie4());
        let d3 = Dim::new(*rng.choice(&KINDS), 1 + rng.below(16), &nvlink4());
        let coll = *rng.choice(&COLLS);
        let s1 = rng.uniform(1e3, 1e9);
        let s2 = s1 * rng.uniform(1.0, 16.0);
        let t1 = time_hier(coll, Bytes::new(s1), &[&d1, &d2, &d3]).raw();
        let t2 = time_hier(coll, Bytes::new(s2), &[&d1, &d2, &d3]).raw();
        assert!(
            t2 >= t1 - 1e-15,
            "{coll:?} over ({:?},{:?},{:?}): S {s1:.3e}->{s2:.3e} but t {t1:.3e}->{t2:.3e}",
            d1.kind,
            d2.kind,
            d3.kind
        );
    });
}

#[test]
fn conversion_algebra_consistency() {
    const LAYOUTS: [Layout; 5] =
        [Layout::Replicated, Layout::Row, Layout::Col, Layout::Head, Layout::Partial];
    check("conversion-algebra", 60, |rng| {
        let from = *rng.choice(&LAYOUTS);
        let to = *rng.choice(&LAYOUTS);
        // identity is free; replicated sources are free
        assert_eq!(conversion_op(from, from), None);
        assert_eq!(conversion_op(Layout::Replicated, to), None);
        // cost is zero iff the op is None
        let dim = Dim::new(DimKind::Ring, 8, &nvlink4());
        let t = conversion_time(from, to, 1e8, &[&dim]).raw();
        match conversion_op(from, to) {
            None => assert_eq!(t, 0.0),
            Some(_) => assert!(t > 0.0),
        }
    });
}

#[test]
fn serving_metrics_sane_across_grid() {
    let model = llama3_8b();
    let sys = sn40l_x16();
    check("serving-sane", 40, |rng| {
        let splits = [(16usize, 1usize), (8, 2), (4, 4), (2, 8), (1, 16)];
        let (tp, pp) = *rng.choice(&splits);
        let pt = ServingPoint {
            tp,
            pp,
            batch: 1.0 + rng.below(16) as f64,
            prompt_len: 128.0 * (1 + rng.below(32)) as f64,
            context: 128.0 * (1 + rng.below(32)) as f64,
        };
        let m = evaluate(&model, &sys, &pt).expect("every grid split covers the group");
        assert!(m.ttft > 0.0 && m.ttft.is_finite());
        assert!(m.tpot > 0.0 && m.tpot.is_finite());
        assert!(m.prefill_tps > 0.0 && m.decode_tps > 0.0);
        // breakdowns are simplices
        for (a, b, c) in [m.prefill_breakdown, m.decode_breakdown] {
            assert!((a + b + c - 1.0).abs() < 1e-9);
            assert!(a >= 0.0 && b >= 0.0 && c >= 0.0);
        }
        // more batch -> more decode throughput (memory-bound weights amortize)
        let big = evaluate(&model, &sys, &ServingPoint { batch: pt.batch * 4.0, ..pt })
            .expect("same split, still feasible");
        assert!(big.decode_tps >= m.decode_tps * 0.999);
    });
}

#[test]
fn serving_rejects_mismatched_splits() {
    let sys = sn40l_x16();
    for (tp, pp) in [(3, 2), (16, 16), (0, 4), (5, 3)] {
        let pt = ServingPoint { tp, pp, batch: 1.0, prompt_len: 128.0, context: 128.0 };
        let e = evaluate(&llama3_8b(), &sys, &pt)
            .expect_err("tp*pp != 16 must be rejected on a 16-chip group");
        assert!(
            e.to_string().contains("serving split"),
            "tp={tp} pp={pp}: unhelpful error {e}"
        );
    }
}

#[test]
fn json_roundtrip_fuzz() {
    // generate random JSON values, serialize, reparse, compare
    fn gen(rng: &mut dfmodel::util::prng::Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.choice(&['a', '"', '\\', 'é', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4)).map(|i| (format!("k{i}"), gen(rng, depth - 1))).collect(),
            ),
        }
    }
    check("json-roundtrip", 150, |rng| {
        let v = gen(rng, 3);
        let compact = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, compact, "compact roundtrip");
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, pretty, "pretty roundtrip");
    });
}
