//! Scale-path integration tests for the cluster engine (PR 10): P²
//! streaming percentiles vs exact within the documented tolerance bands,
//! calendar-queue determinism across runs, fleet-mode parity with the
//! single-replica path, and request-count-independent memory. The
//! tolerances pinned here are the ones DESIGN.md §Cluster at scale
//! documents; they were measured in `python/tests/mirror_cluster.py`.

use dfmodel::cluster::engine::{
    percentiles, simulate, simulate_stream, Pcts, ReplicaConfig, SimOptions, Slo,
};
use dfmodel::cluster::stream::StreamingPcts;
use dfmodel::cluster::workload::{Arrivals, LengthDist, TraceSpec};
use dfmodel::graph::llama::llama3_8b;
use dfmodel::serving::sn40l_x16;
use dfmodel::util::prng::Rng;

fn cfg() -> ReplicaConfig {
    ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1)
}

fn slo() -> Slo {
    Slo { ttft: 1.0, tpot: 0.02 }
}

/// Relative error of each P² percentile vs the exact summary of the same
/// samples, as (mean, p50, p95, p99).
fn rel_errs(samples: &[f64]) -> [f64; 4] {
    let mut sp = StreamingPcts::new();
    for &x in samples {
        sp.observe(x);
    }
    let est = sp.pcts();
    let exact = percentiles(samples.to_vec());
    let rel = |e: f64, x: f64| (e - x).abs() / x;
    [
        rel(est.mean, exact.mean),
        rel(est.p50, exact.p50),
        rel(est.p95, exact.p95),
        rel(est.p99, exact.p99),
    ]
}

#[test]
fn p2_within_documented_band_on_smooth_streams() {
    // exponential and log-normal latency-like streams: the documented
    // 5% (p50/p95) / 10% (p99) band, worst case over 10 seeds each
    let mut worst = [0.0f64; 4];
    for seed in 0..10u64 {
        let mut rng = Rng::new(100 + seed);
        let expo: Vec<f64> = (0..20_000).map(|_| rng.exp(2.0)).collect();
        let logn: Vec<f64> = (0..20_000).map(|_| rng.lognormal_mean(0.3, 0.6)).collect();
        for s in [&expo, &logn] {
            for (w, e) in worst.iter_mut().zip(rel_errs(s)) {
                *w = w.max(e);
            }
        }
    }
    assert!(worst[0] < 1e-9, "the mean must be exact, err {}", worst[0]);
    assert!(worst[1] < 0.05, "p50 err {} exceeds the 5% band", worst[1]);
    assert!(worst[2] < 0.05, "p95 err {} exceeds the 5% band", worst[2]);
    assert!(worst[3] < 0.10, "p99 err {} exceeds the 10% band", worst[3]);
}

#[test]
fn p2_within_documented_band_on_saturated_bursty_sim() {
    // the documented hard case: under saturated bursty traffic, queue
    // delay is strongly bimodal (burst crests wait ~1 s, troughs ~0) and
    // P² degrades — this is exactly what `exact_percentiles` is for. The
    // exact and streaming runs share one event history, so every
    // difference below is pure estimator error.
    let spec = TraceSpec {
        seed: 11,
        n_requests: 4000,
        arrivals: Arrivals::Bursty { base: 2.0, peak: 16.0, period: 30.0 },
        prompt: LengthDist { mean: 1024.0, sigma: 0.4, min: 16, max: 8192 },
        output: LengthDist { mean: 128.0, sigma: 0.6, min: 2, max: 2048 },
    };
    let exact =
        simulate_stream(&cfg(), 1, &spec, &slo(), &SimOptions { exact_percentiles: true })
            .unwrap();
    let est = simulate_stream(&cfg(), 1, &spec, &slo(), &SimOptions::default()).unwrap();
    assert_eq!(exact.events, est.events, "paths must share one event history");
    let rel = |e: f64, x: f64| (e - x).abs() / x;
    let band = |e: &Pcts, x: &Pcts| [rel(e.p50, x.p50), rel(e.p95, x.p95), rel(e.p99, x.p99)];
    let ett = band(&est.ttft, &exact.ttft);
    assert!(ett[1] < 0.15 && ett[2] < 0.15, "ttft p95/p99 err {ett:?} exceeds 15%");
    let etp = band(&est.tpot, &exact.tpot);
    assert!(etp.iter().all(|&e| e < 0.10), "tpot err {etp:?} exceeds 10%");
    let eq = band(&est.queue, &exact.queue);
    assert!(eq.iter().all(|&e| e < 0.40), "bimodal queue err {eq:?} exceeds 40% worst case");
}

#[test]
fn streaming_runs_are_deterministic() {
    // calendar-queue + arena path: identical spec in, bitwise-identical
    // summaries out, on both arrival processes
    for spec in [
        TraceSpec::poisson(3, 8.0, 500),
        TraceSpec {
            seed: 5,
            n_requests: 500,
            arrivals: Arrivals::Bursty { base: 2.0, peak: 10.0, period: 30.0 },
            prompt: LengthDist { mean: 1024.0, sigma: 0.4, min: 16, max: 8192 },
            output: LengthDist { mean: 128.0, sigma: 0.6, min: 2, max: 2048 },
        },
    ] {
        let a = simulate_stream(&cfg(), 2, &spec, &slo(), &SimOptions::default()).unwrap();
        let b = simulate_stream(&cfg(), 2, &spec, &slo(), &SimOptions::default()).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.ttft, b.ttft);
        assert_eq!(a.queue, b.queue);
        assert_eq!(a.tpot, b.tpot);
        assert_eq!(a.peak_in_flight, b.peak_in_flight);
    }
}

#[test]
fn fleet_mode_tracks_the_single_replica_path() {
    // R replicas at R·rate ≈ 1 replica at rate: least-loaded dispatch
    // de-randomizes per-replica arrivals (per-step batches run a little
    // smaller than true Poisson splitting), so mean TPOT gets a 25% band;
    // attainment and throughput scaling are tight.
    let one = simulate(&cfg(), 1, &TraceSpec::poisson(3, 4.0, 400).generate(), &slo()).unwrap();
    let fleet =
        simulate(&cfg(), 4, &TraceSpec::poisson(3, 16.0, 1600).generate(), &slo()).unwrap();
    assert!(
        (fleet.tpot.mean / one.tpot.mean - 1.0).abs() < 0.25,
        "mean TPOT {} vs {}",
        fleet.tpot.mean,
        one.tpot.mean
    );
    assert!(
        (fleet.slo_attainment - one.slo_attainment).abs() < 0.05,
        "attainment {} vs {}",
        fleet.slo_attainment,
        one.slo_attainment
    );
    let ratio = fleet.throughput_rps / one.throughput_rps;
    assert!((ratio - 4.0).abs() < 0.4, "throughput must scale ~4x, got {ratio:.2}x");
}

#[test]
fn memory_tracks_load_not_trace_length() {
    // 10x the requests at the same offered load: the in-flight peak (the
    // engine's memory footprint) must not grow with trace length
    let opts = SimOptions::default();
    let small =
        simulate_stream(&cfg(), 4, &TraceSpec::poisson(9, 32.0, 2000), &slo(), &opts).unwrap();
    let big =
        simulate_stream(&cfg(), 4, &TraceSpec::poisson(9, 32.0, 20_000), &slo(), &opts)
            .unwrap();
    assert_eq!(big.n_completed, 20_000);
    assert!(
        big.peak_in_flight < 4 * small.peak_in_flight + 64,
        "peak_in_flight grew with trace length: {} (2k) vs {} (20k)",
        small.peak_in_flight,
        big.peak_in_flight
    );
}
