//! Daemon end-to-end tests over a loopback socket on ephemeral ports:
//! HTTP evaluate parity with direct `Scenario::evaluate` (byte-identical
//! report JSON for every committed example scenario), LRU cache hits on
//! repeated POSTs (counter + single optimizer span in the trace), lint
//! rejection with DF-XNNN diagnostics, queue-full backpressure (429),
//! per-request timeout (503), and graceful shutdown draining in-flight
//! work. The backpressure/timeout/drain tests inject gated evaluators via
//! `Server::bind_with` so their timing is deterministic.

use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use dfmodel::api::Scenario;
use dfmodel::daemon::{http, Config, Server, Service, ServiceConfig};
use dfmodel::obs;
use dfmodel::util::json::Json;

fn scenario_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

fn read_scenario(name: &str) -> String {
    let path = scenario_dir().join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// Ephemeral-port config with small pool/queue sizes for test determinism.
fn test_config(service: ServiceConfig) -> Config {
    Config { addr: "127.0.0.1:0".parse().unwrap(), service, ..Config::default() }
}

fn start_default_server() -> dfmodel::daemon::Handle {
    let cfg = test_config(ServiceConfig { workers: 2, ..ServiceConfig::default() });
    Server::bind(&cfg).expect("bind").start().expect("start")
}

fn post_evaluate(addr: SocketAddr, body: &str) -> (u16, String) {
    http::roundtrip(addr, "POST", "/v1/evaluate", Some(body)).expect("evaluate roundtrip")
}

fn metrics_counter(addr: SocketAddr, name: &str) -> f64 {
    let (status, body) =
        http::roundtrip(addr, "GET", "/v1/metrics?format=json", None).expect("metrics");
    assert_eq!(status, 200, "metrics body: {body}");
    Json::parse(&body)
        .expect("metrics json")
        .get(name)
        .and_then(|m| m.get("value"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0)
}

#[test]
fn health_endpoint_answers() {
    let h = start_default_server();
    let (status, body) = http::roundtrip(h.addr(), "GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).expect("health json");
    assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("ok"));
    assert_eq!(j.get("service").and_then(|v| v.as_str()), Some("dfmodeld"));
    h.stop().unwrap();
}

#[test]
fn unknown_routes_and_methods_are_rejected() {
    let h = start_default_server();
    let (status, _) = http::roundtrip(h.addr(), "GET", "/v2/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = http::roundtrip(h.addr(), "DELETE", "/v1/health", None).unwrap();
    assert_eq!(status, 405);
    let (status, body) = post_evaluate(h.addr(), "{ not json");
    assert_eq!(status, 400, "body: {body}");
    h.stop().unwrap();
}

/// Acceptance pin: HTTP evaluate output is byte-identical to the direct
/// `Scenario::evaluate` report JSON for every committed example scenario.
#[test]
fn evaluate_parity_with_direct_facade_on_all_example_scenarios() {
    let h = start_default_server();
    for name in ["llm_dgx.json", "serve_sn40l.json", "explore_small.json"] {
        let text = read_scenario(name);
        let direct = Scenario::parse(&text)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .evaluate()
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .to_json()
            .pretty();
        let (status, body) = post_evaluate(h.addr(), &text);
        assert_eq!(status, 200, "{name}: {body}");
        assert_eq!(body, direct, "{name}: HTTP report must be byte-identical to the facade");
    }
    h.stop().unwrap();
}

#[test]
fn repeat_post_is_served_from_the_cache() {
    let h = start_default_server();
    let text = read_scenario("llm_dgx.json");
    let (s1, first) = post_evaluate(h.addr(), &text);
    assert_eq!(s1, 200, "{first}");
    assert_eq!(metrics_counter(h.addr(), "daemon.cache.misses"), 1.0);
    let (s2, second) = post_evaluate(h.addr(), &text);
    assert_eq!(s2, 200);
    assert_eq!(second, first, "cached reply must be the identical bytes");
    assert_eq!(metrics_counter(h.addr(), "daemon.cache.hits"), 1.0);
    // same document with reordered keys / different whitespace: the
    // canonical (sorted) cache key still hits
    let reordered = Json::parse(&text).unwrap().sorted().pretty();
    let (s3, third) = post_evaluate(h.addr(), &reordered);
    assert_eq!(s3, 200);
    assert_eq!(third, first);
    assert_eq!(metrics_counter(h.addr(), "daemon.cache.hits"), 2.0);
    assert_eq!(
        metrics_counter(h.addr(), "daemon.evaluate.ok"),
        1.0,
        "only the first request may reach the optimizer"
    );
    h.stop().unwrap();
}

/// The trace seen by a capture stays worker-count independent and a cache
/// hit records no second optimizer span (in-process service, no socket:
/// `obs` captures are thread-scoped).
#[test]
fn cache_hit_records_no_second_optimizer_span() {
    let svc = Service::new(&ServiceConfig { workers: 2, ..ServiceConfig::default() });
    let text = read_scenario("llm_dgx.json");
    let sess = obs::start_capture();
    let r1 = svc.evaluate(text.as_bytes());
    let r2 = svc.evaluate(text.as_bytes());
    let cap = obs::finish_capture(sess);
    assert_eq!((r1.status, r2.status), (200, 200));
    assert_eq!(r2.body, r1.body);
    let tree = cap.structure();
    let optimizer_spans = tree.matches("scenario.evaluate").count();
    assert_eq!(optimizer_spans, 1, "cache hit must not re-run the optimizer:\n{tree}");
    assert_eq!(svc.metrics().counter_value("daemon.cache.hits"), 1);
}

#[test]
fn lint_failing_scenario_is_422_with_diagnostics() {
    let h = start_default_server();
    let bad = read_scenario("bad/s001_negative_bandwidth.json");
    let (status, body) = post_evaluate(h.addr(), &bad);
    assert_eq!(status, 422, "body: {body}");
    let j = Json::parse(&body).expect("422 body is json");
    assert_eq!(j.get("error").and_then(|v| v.as_str()), Some("scenario fails lint"));
    assert!(body.contains("DF-S001"), "diagnostic code missing from: {body}");
    assert_eq!(metrics_counter(h.addr(), "daemon.evaluate.lint_rejected"), 1.0);
    h.stop().unwrap();
}

/// A gate the injected evaluators block on, plus a counter of evaluations
/// that have started (so tests can sequence deterministically).
struct Gate {
    state: Mutex<(usize, bool)>, // (started, open)
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { state: Mutex::new((0, false)), cv: Condvar::new() })
    }

    /// Called by the evaluator: registers the start, then blocks until open.
    fn enter(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 += 1;
        self.cv.notify_all();
        while !st.1 {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn wait_started(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        while st.0 < n {
            let (guard, timeout) =
                self.cv.wait_timeout(st, Duration::from_secs(30)).unwrap();
            st = guard;
            assert!(!timeout.timed_out(), "evaluator never started");
        }
    }

    fn open(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        self.cv.notify_all();
    }
}

fn gated_server(workers: usize, queue_cap: usize) -> (dfmodel::daemon::Handle, Arc<Gate>) {
    let gate = Gate::new();
    let g = Arc::clone(&gate);
    let svc = Service::with_evaluator(
        &ServiceConfig {
            workers,
            queue_cap,
            cache_entries: 0, // every request must reach the evaluator
            timeout: Duration::from_secs(60),
        },
        Arc::new(move |_j: &Json| {
            g.enter();
            Ok("{\"done\": true}".to_string())
        }),
    );
    let cfg = test_config(ServiceConfig::default());
    let h = Server::bind_with(&cfg, svc).expect("bind").start().expect("start");
    (h, gate)
}

#[test]
fn full_queue_rejects_with_429() {
    let (h, gate) = gated_server(1, 1);
    let addr = h.addr();
    // A occupies the single worker...
    let a = std::thread::spawn(move || post_evaluate(addr, r#"{"lint": false, "req": "a"}"#));
    gate.wait_started(1);
    // ...B fills the queue (poll the submitted counter until it is in)...
    let b = std::thread::spawn(move || post_evaluate(addr, r#"{"lint": false, "req": "b"}"#));
    let deadline = Instant::now() + Duration::from_secs(30);
    while metrics_counter(addr, "daemon.evaluate.submitted") < 2.0 {
        assert!(Instant::now() < deadline, "second request never queued");
        std::thread::sleep(Duration::from_millis(5));
    }
    // ...so C must bounce with 429
    let (status, body) = post_evaluate(addr, r#"{"lint": false, "req": "c"}"#);
    assert_eq!(status, 429, "body: {body}");
    assert!(metrics_counter(addr, "daemon.rejected.queue_full") >= 1.0);
    gate.open();
    assert_eq!(a.join().unwrap().0, 200);
    assert_eq!(b.join().unwrap().0, 200);
    h.stop().unwrap();
}

#[test]
fn slow_evaluation_times_out_with_503() {
    let svc = Service::with_evaluator(
        &ServiceConfig {
            workers: 1,
            queue_cap: 4,
            cache_entries: 0,
            timeout: Duration::from_millis(50),
        },
        // sleeps through the deadline but finishes on its own, so shutdown
        // never hangs on the orphaned job
        Arc::new(|_j: &Json| {
            std::thread::sleep(Duration::from_millis(300));
            Ok("{}".to_string())
        }),
    );
    let h = Server::bind_with(&test_config(ServiceConfig::default()), svc)
        .expect("bind")
        .start()
        .expect("start");
    let (status, body) = post_evaluate(h.addr(), r#"{"lint": false}"#);
    assert_eq!(status, 503, "body: {body}");
    assert_eq!(metrics_counter(h.addr(), "daemon.rejected.timeout"), 1.0);
    h.stop().unwrap();
}

/// Graceful shutdown: stop() refuses new connections but the in-flight
/// request completes with 200 before the server exits.
#[test]
fn shutdown_drains_in_flight_requests() {
    let (h, gate) = gated_server(1, 4);
    let addr = h.addr();
    let inflight =
        std::thread::spawn(move || post_evaluate(addr, r#"{"lint": false, "req": "slow"}"#));
    gate.wait_started(1);
    // stop while the request is still running; stop() must block on the drain
    let stopper = std::thread::spawn(move || h.stop());
    std::thread::sleep(Duration::from_millis(100));
    assert!(!stopper.is_finished(), "stop() must wait for the in-flight request");
    gate.open();
    let (status, body) = inflight.join().unwrap();
    assert_eq!((status, body.as_str()), (200, "{\"done\": true}"));
    stopper.join().unwrap().expect("clean shutdown");
    // the listener is gone: new connections are refused
    let refused = std::net::TcpStream::connect_timeout(&addr, Duration::from_millis(500));
    assert!(refused.is_err(), "listener must be closed after stop()");
}

#[test]
fn oversized_body_is_413() {
    let cfg = Config {
        max_body: 64,
        ..test_config(ServiceConfig { workers: 1, ..ServiceConfig::default() })
    };
    let h = Server::bind(&cfg).expect("bind").start().expect("start");
    let big = format!(r#"{{"lint": false, "pad": "{}"}}"#, "x".repeat(256));
    let (status, body) = post_evaluate(h.addr(), &big);
    assert_eq!(status, 413, "body: {body}");
    h.stop().unwrap();
}

#[test]
fn metrics_text_mirrors_the_obs_format() {
    let h = start_default_server();
    let (status, _) = http::roundtrip(h.addr(), "GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    let text = read_scenario("llm_dgx.json");
    post_evaluate(h.addr(), &text);
    let (status, body) = http::roundtrip(h.addr(), "GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(body.starts_with("stats    : "), "got: {body}");
    assert!(body.contains("  daemon.evaluate.requests = 1"), "got: {body}");
    assert!(body.contains("daemon.evaluate.latency_seconds: n=1"), "got: {body}");
    h.stop().unwrap();
}
