//! Explorer property tests: frontier laws, determinism across worker
//! counts, prune-vs-exhaustive equivalence on a ≥500-point space, budget
//! semantics, paper-grid parity with the fixed `dse` systems, and the
//! scenario-level explore goal (serde + report consistency).

use dfmodel::api::{self, ExploreOptions, Scenario};
use dfmodel::dse::{self, Workload};
use dfmodel::explore::{
    explore, pareto, ChipCfg, ExploreOutcome, ExploreSettings, MemCfg, SearchSpace, WorkloadSpec,
};
use dfmodel::graph::gpt::GptConfig;
use dfmodel::system::{chip, interconnect, memory, topology, SystemSpec};

/// A small GPT so one optimizer evaluation is cheap in debug builds.
fn tiny_gpt() -> GptConfig {
    GptConfig {
        layers: 8,
        d_model: 1024.0,
        n_heads: 8.0,
        seq: 512.0,
        d_ff: 4096.0,
        vocab: 32000.0,
        dtype_bytes: 2.0,
    }
}

/// High-compute, tiny-SRAM kernel-by-kernel parts paired with slow DRAM:
/// their roofline bound is far below what the good chips achieve, so the
/// pruner can discard them once the frontier is seeded.
fn junk_chip(i: usize) -> ChipCfg {
    ChipCfg::Custom {
        name: format!("junk-{i}"),
        compute_tflops: 1000.0 + 250.0 * i as f64,
        sram_mb: 16.0,
        dataflow: false,
        tiles: None,
        power_w: None,
        price_usd: None,
    }
}

fn tiny_workload() -> WorkloadSpec {
    WorkloadSpec {
        kind: Workload::Llm,
        gpt: Some(tiny_gpt()),
        batch: Some(32.0),
        state_bytes_per_weight_byte: None,
    }
}

/// 4 chips × 2 mems × 2 links × 2 topologies = 32 candidates at 8 chips.
fn small_space() -> SearchSpace {
    SearchSpace {
        workload: tiny_workload(),
        chips: vec![ChipCfg::named("sn30"), ChipCfg::named("h100"), junk_chip(0), junk_chip(4)],
        mems: vec![
            MemCfg::named("hbm3"),
            MemCfg { name: "ddr4".into(), bandwidth_gbs: Some(25.0), capacity_gb: None },
        ],
        links: vec!["nvlink4".into(), "pcie4".into()],
        topologies: vec!["torus2d".into(), "ring".into()],
        chip_counts: vec![8],
        batches: vec![None],
    }
}

/// 16 chips × 2 mems × 2 links × 2 topologies × 2 counts × 2 batches = 512.
fn big_space() -> SearchSpace {
    let mut chips = vec![ChipCfg::named("sn30"), ChipCfg::named("tpuv4")];
    for i in 0..14 {
        chips.push(junk_chip(i));
    }
    SearchSpace {
        chips,
        chip_counts: vec![8, 16],
        batches: vec![None, Some(64.0)],
        ..small_space()
    }
}

fn objectives(out: &ExploreOutcome) -> Vec<[f64; 3]> {
    out.points.iter().map(|p| [p.utilization, p.cost_eff, p.power_eff]).collect()
}

/// Identity + objective bits of one point (for cross-run comparison).
fn point_key(out: &ExploreOutcome, i: usize) -> String {
    let p = &out.points[i];
    format!(
        "{}|{}|{}|{}|{:?}|{:x}|{:x}|{:x}",
        p.chip,
        p.topo,
        p.mem,
        p.link,
        out.point_batches[i],
        p.utilization.to_bits(),
        p.cost_eff.to_bits(),
        p.power_eff.to_bits()
    )
}

#[test]
fn frontier_is_mutually_nondominated_and_covers_dominated_points() {
    let out = explore(&small_space(), &ExploreSettings::exhaustive()).unwrap();
    assert_eq!(out.points.len(), out.candidates, "exhaustive mode visits everything");
    let objs = objectives(&out);
    for &i in &out.frontier {
        for &j in &out.frontier {
            assert!(
                i == j || !pareto::dominates(&objs[i], &objs[j]),
                "frontier point {j} dominated by frontier point {i}"
            );
        }
    }
    for (j, o) in objs.iter().enumerate() {
        if o.iter().all(|v| v.is_finite()) && !out.frontier.contains(&j) {
            assert!(
                out.frontier.iter().any(|&i| pareto::dominates(&objs[i], o)),
                "dominated point {j} not covered by any frontier point"
            );
        }
    }
    assert!(!out.frontier.is_empty());
    assert_eq!(out.dominated(), out.feasible() - out.frontier.len());
}

#[test]
fn outcome_deterministic_across_worker_counts() {
    let space = small_space();
    let run = |workers: usize| {
        explore(&space, &ExploreSettings { workers: Some(workers), ..Default::default() })
            .unwrap()
    };
    let one = run(1);
    for other in [run(3), run(4)] {
        assert_eq!(one.frontier, other.frontier);
        assert_eq!(one.evaluated, other.evaluated);
        assert_eq!(one.cache_hits, other.cache_hits);
        assert_eq!(one.pruned, other.pruned);
        assert_eq!(one.infeasible, other.infeasible);
        assert_eq!(one.points.len(), other.points.len());
        for i in 0..one.points.len() {
            assert_eq!(point_key(&one, i), point_key(&other, i));
        }
    }
}

#[test]
fn pruning_preserves_frontier_and_evaluates_fewer_points() {
    let space = big_space();
    let full = explore(&space, &ExploreSettings::exhaustive()).unwrap();
    let pruned = explore(&space, &ExploreSettings::default()).unwrap();
    assert!(full.candidates >= 500, "space must cover >= 500 points, got {}", full.candidates);
    assert_eq!(full.evaluated + full.cache_hits, full.candidates);

    let mut fa: Vec<String> = full.frontier.iter().map(|&i| point_key(&full, i)).collect();
    let mut fb: Vec<String> = pruned.frontier.iter().map(|&i| point_key(&pruned, i)).collect();
    fa.sort();
    fb.sort();
    assert_eq!(fa, fb, "pruning changed the Pareto frontier");

    assert!(pruned.pruned > 0, "no candidate was pruned");
    assert!(
        pruned.evaluated < full.evaluated,
        "pruning must evaluate fewer points: {} vs {}",
        pruned.evaluated,
        full.evaluated
    );
    let accounted =
        pruned.evaluated + pruned.cache_hits + pruned.pruned + pruned.skipped_budget;
    assert_eq!(accounted, pruned.candidates);
}

#[test]
fn paper_grid_reproduces_dse_systems_exactly() {
    for w in Workload::all() {
        let cands = SearchSpace::paper_grid(w).candidates().unwrap();
        let systems = dse::dse_systems_1024();
        assert_eq!(cands.len(), systems.len(), "{w:?}");
        for (c, s) in cands.iter().zip(systems) {
            assert_eq!(c.batch, None);
            assert_eq!(c.sys.describe(), s.describe());
            assert_eq!(c.sys.chip.tiles, s.chip.tiles);
            assert_eq!(c.sys.chip.tflop_per_tile.to_bits(), s.chip.tflop_per_tile.to_bits());
            assert_eq!(c.sys.chip.sram_bytes.to_bits(), s.chip.sram_bytes.to_bits());
            assert_eq!(c.sys.chip.execution, s.chip.execution);
            assert_eq!(c.sys.chip.power_w.to_bits(), s.chip.power_w.to_bits());
            assert_eq!(c.sys.chip.price_usd.to_bits(), s.chip.price_usd.to_bits());
            assert_eq!(c.sys.memory.bandwidth.to_bits(), s.memory.bandwidth.to_bits());
            assert_eq!(c.sys.memory.capacity.to_bits(), s.memory.capacity.to_bits());
            assert_eq!(c.sys.link.bandwidth.to_bits(), s.link.bandwidth.to_bits());
            assert_eq!(c.sys.link.latency.to_bits(), s.link.latency.to_bits());
            assert_eq!(c.sys.topology.dim_sizes(), s.topology.dim_sizes());
        }
    }
}

/// One §VI-C system end to end through the explorer must equal the direct
/// design-point evaluation bit for bit (`dse::sweep` parity at full scale).
#[test]
fn explorer_evaluation_matches_design_point_at_paper_scale() {
    let space = SearchSpace {
        workload: WorkloadSpec {
            kind: Workload::Llm,
            gpt: None,
            batch: None,
            state_bytes_per_weight_byte: None,
        },
        chips: vec![ChipCfg::named("h100")],
        mems: vec![MemCfg::named("hbm3")],
        links: vec!["nvlink4".into()],
        topologies: vec!["torus2d".into()],
        chip_counts: vec![1024],
        batches: vec![None],
    };
    let out = explore(&space, &ExploreSettings::exhaustive()).unwrap();
    assert_eq!(out.points.len(), 1);
    let link = interconnect::nvlink4();
    let sys = SystemSpec::new(
        chip::h100(),
        memory::hbm3(),
        link.clone(),
        topology::torus2d(32, 32, &link),
    );
    let direct = api::evaluate_design(Workload::Llm, &sys).expect("feasible");
    let p = &out.points[0];
    assert_eq!(p.utilization.to_bits(), direct.utilization.to_bits());
    assert_eq!(p.cost_eff.to_bits(), direct.cost_eff.to_bits());
    assert_eq!(p.power_eff.to_bits(), direct.power_eff.to_bits());
    assert_eq!(p.achieved_flops.to_bits(), direct.achieved_flops.to_bits());
    assert_eq!(p.breakdown.0.to_bits(), direct.breakdown.0.to_bits());
    assert_eq!(p.breakdown.1.to_bits(), direct.breakdown.1.to_bits());
    assert_eq!(p.breakdown.2.to_bits(), direct.breakdown.2.to_bits());
}

#[test]
fn budget_caps_visited_candidates() {
    let out = explore(
        &small_space(),
        &ExploreSettings { prune: false, budget: Some(5), ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.points.len(), 5);
    assert_eq!(out.skipped_budget, out.candidates - 5);
    assert_eq!(out.evaluated + out.cache_hits, 5);
}

#[test]
fn aliasing_batch_axes_hit_the_cache() {
    // batch override equal to the workload batch → same canonical key
    let space = SearchSpace {
        chips: vec![ChipCfg::named("sn30")],
        mems: vec![MemCfg::named("hbm3")],
        links: vec!["nvlink4".into()],
        topologies: vec!["ring".into()],
        batches: vec![None, Some(32.0)],
        ..small_space()
    };
    let out = explore(&space, &ExploreSettings::exhaustive()).unwrap();
    assert_eq!(out.candidates, 2);
    assert_eq!(out.evaluated, 1);
    assert_eq!(out.cache_hits, 1);
    assert_eq!(point_key(&out, 0), point_key(&out, 1));
}

#[test]
fn fixed_size_workloads_alias_across_the_batch_axis() {
    // HPL's problem size is fixed: a batch axis must hit the cache, not
    // force duplicate optimizer runs or batch-labeled duplicate rows
    let space = SearchSpace {
        workload: WorkloadSpec {
            kind: Workload::Hpl,
            gpt: None,
            batch: None,
            state_bytes_per_weight_byte: None,
        },
        chips: vec![ChipCfg::named("tpuv4")],
        mems: vec![MemCfg::named("hbm3")],
        links: vec!["nvlink4".into()],
        topologies: vec!["torus2d".into()],
        chip_counts: vec![16],
        batches: vec![None, Some(7.0)],
    };
    let out = explore(&space, &ExploreSettings::exhaustive()).unwrap();
    assert_eq!(out.candidates, 2);
    assert_eq!(out.evaluated, 1);
    assert_eq!(out.cache_hits, 1);
    assert_eq!(out.point_batches, vec![None, None]);
}

/// Pin for the memo-cache extraction into `util::lru`: a point served from
/// the cache must be bitwise identical to the same system evaluated fresh
/// in a run with no aliasing (no cache hits at all), and repeated runs of
/// the memoized sweep must agree bit for bit.
#[test]
fn memo_cache_output_is_bitwise_identical_to_fresh_evaluation() {
    let aliased = SearchSpace {
        chips: vec![ChipCfg::named("sn30")],
        mems: vec![MemCfg::named("hbm3")],
        links: vec!["nvlink4".into()],
        topologies: vec!["ring".into()],
        // batch override equal to the workload batch → one eval + one hit
        batches: vec![None, Some(32.0)],
        ..small_space()
    };
    let fresh_space = SearchSpace { batches: vec![None], ..aliased.clone() };

    let hit = explore(&aliased, &ExploreSettings::exhaustive()).unwrap();
    assert_eq!((hit.evaluated, hit.cache_hits), (1, 2 - 1));
    let fresh = explore(&fresh_space, &ExploreSettings::exhaustive()).unwrap();
    assert_eq!((fresh.evaluated, fresh.cache_hits), (1, 0));

    // both the evaluated and the cache-served point match the cache-free run
    let p = &fresh.points[0];
    for q in &hit.points {
        assert_eq!(q.utilization.to_bits(), p.utilization.to_bits());
        assert_eq!(q.cost_eff.to_bits(), p.cost_eff.to_bits());
        assert_eq!(q.power_eff.to_bits(), p.power_eff.to_bits());
        assert_eq!(q.achieved_flops.to_bits(), p.achieved_flops.to_bits());
    }

    // and the memoized sweep is reproducible bit for bit
    let again = explore(&aliased, &ExploreSettings::exhaustive()).unwrap();
    assert_eq!(again.frontier, hit.frontier);
    for i in 0..hit.points.len() {
        assert_eq!(point_key(&hit, i), point_key(&again, i));
    }
}

#[test]
fn scenario_explore_roundtrips_and_reports() {
    let opts = ExploreOptions {
        chips: vec![
            ChipCfg::named("sn30"),
            ChipCfg::Custom {
                name: "mini".into(),
                compute_tflops: 500.0,
                sram_mb: 128.0,
                dataflow: true,
                tiles: Some(512),
                power_w: None,
                price_usd: None,
            },
        ],
        mems: vec![
            MemCfg::named("ddr4"),
            MemCfg { name: "hbm3".into(), bandwidth_gbs: Some(2000.0), capacity_gb: Some(64.0) },
        ],
        links: vec!["pcie4".into()],
        topologies: vec!["ring".into(), "torus2d".into()],
        chip_counts: vec![8],
        batches: vec![None, Some(16.0)],
        prune: true,
        budget: Some(64),
        top: 4,
    };
    let s = Scenario::llm_custom(tiny_gpt()).batch(16.0).explore(opts);
    let text = s.to_json().pretty();
    let back = Scenario::parse(&text).expect("explore scenario parses");
    assert_eq!(s, back, "explore scenario changed across serde:\n{text}");

    let r = back.evaluate().unwrap();
    let e = r.explore.as_ref().expect("explore section");
    assert_eq!(e.candidates, 16);
    assert_eq!(e.candidates, e.evaluated + e.cache_hits + e.pruned + e.skipped_budget);
    assert!(e.frontier_size >= 1);
    assert!(e.frontier.len() <= 4, "report frontier bounded by top");
    let json = r.to_json();
    let ex = json.get("explore").expect("explore json section");
    assert!(ex.get("frontier").is_some());
    assert!(ex.get("candidates").is_some());
    assert!(r.frontier().is_some());
}
