//! Explain-layer integration tests: the acceptance pin on the committed
//! DGX-scale scenario, the paper-workload goldens, the exact-sum property
//! over a scenario grid, bit-parity of unexplained runs, and the stable
//! render-tail ordering (lint warnings before the span/metrics footer).

use std::path::Path;

use dfmodel::api::{Scenario, SystemCfg};

fn scenario_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

/// Relative exact-sum tolerance of the attribution decomposition.
const SUM_TOL: f64 = 1e-9;

fn assert_attribution_exact(a: &dfmodel::explain::Attribution) {
    for (name, v) in [
        ("compute", a.levels.compute),
        ("sram", a.levels.sram),
        ("dram", a.levels.dram),
        ("interchip", a.levels.interchip),
        ("bubble", a.levels.bubble),
    ] {
        assert!(v >= 0.0, "level {name} share must be non-negative, got {v}");
    }
    let sum = a.levels.sum();
    assert!(
        (sum - a.total).abs() <= SUM_TOL * a.total.max(1e-30),
        "levels sum {sum} != total {}",
        a.total
    );
    let ksum: f64 = a.kernels.iter().map(|k| k.seconds).sum();
    assert!(a.kernels.iter().all(|k| k.seconds >= 0.0), "kernel shares must be non-negative");
    assert!(
        ksum <= a.total * (1.0 + SUM_TOL),
        "kernel shares {ksum} exceed the step total {}",
        a.total
    );
}

#[test]
fn llm_dgx_explain_pins_attribution_audit_and_sensitivity() {
    let s = Scenario::load(&scenario_dir().join("llm_dgx.json")).expect("load scenario");
    let r = s.explained().evaluate().expect("feasible");
    let e = r.explain.as_ref().expect("explained run fills the section");

    // 1. roofline attribution: exact sum, named binding resource
    let a = e.attribution.as_ref().expect("map goal records attribution");
    assert_attribution_exact(a);
    assert_eq!(a.total, r.step_time().unwrap(), "attribution explains the reported step time");
    assert!(["compute", "sram", "dram", "interchip", "bubble"].contains(&a.binding));
    assert!(!a.kernels.is_empty(), "per-kernel shares present");

    // 2. decision audit: non-empty rejected ledger with dominating terms
    let audit = e.audit.as_ref().expect("optimizer phases recorded");
    assert!(!audit.phases.is_empty());
    assert!(
        audit.phases.iter().any(|p| !p.rejected.is_empty()),
        "at least one phase keeps rejected candidates"
    );
    for p in &audit.phases {
        assert!(p.rejected.len() <= audit.top, "phase {} overflows top-K", p.phase);
        for c in p.best.iter().chain(&p.rejected) {
            assert!(!c.dominating.is_empty(), "{}: candidate without dominating term", p.phase);
        }
    }

    // 3. sensitivity: one row per knob, ranked by |elasticity| descending
    assert_eq!(e.sensitivity.len(), 6, "five continuous knobs + chip count");
    assert!(e.sensitivity.iter().any(|x| x.elasticity.is_some()));
    let mags: Vec<Option<f64>> =
        e.sensitivity.iter().map(|x| x.elasticity.map(f64::abs)).collect();
    for w in mags.windows(2) {
        match (w[0], w[1]) {
            (Some(x), Some(y)) => assert!(x >= y, "rows not ranked: {x} < {y}"),
            (None, Some(_)) => panic!("infeasible rows must rank last"),
            _ => {}
        }
    }

    // the CI smoke run jq-asserts these stable keys
    let j = r.to_json();
    let attr = j.get("explain").unwrap().get("attribution").unwrap();
    let levels = attr.get("levels").unwrap();
    let jsum: f64 = ["compute_s", "sram_s", "dram_s", "interchip_s", "bubble_s"]
        .iter()
        .map(|k| levels.get(k).unwrap().as_f64().unwrap())
        .sum();
    let total = attr.get("total_s").unwrap().as_f64().unwrap();
    assert!((jsum - total).abs() <= SUM_TOL * total, "JSON shares must sum to total_s");
}

#[test]
fn unexplained_runs_stay_bit_identical() {
    let s = Scenario::load(&scenario_dir().join("llm_dgx.json")).expect("load scenario");
    let plain = s.evaluate().expect("feasible");
    let mut explained = s.explained().evaluate().expect("feasible");
    explained.explain = None;
    assert_eq!(
        plain.to_json().pretty(),
        explained.to_json().pretty(),
        "stripping the explain section must recover the unexplained report bytes"
    );
    assert!(!plain.to_json().pretty().contains("\"explain\""));
}

#[test]
fn paper_workload_goldens_keep_the_exact_sum_invariant() {
    // the same reference systems the "explain" figure renders; the LLM
    // point is the committed DGX-scale scenario and must be feasible
    let mut feasible = 0;
    for w in ["llm", "dlrm", "hpl", "fft"] {
        let mut s = dfmodel::figures::explain_figs::paper_scenario(w).expect("known workload");
        s.explain.sensitivity = false;
        let Ok(r) = s.evaluate() else {
            assert_ne!(w, "llm", "the LLM reference point matches llm_dgx.json");
            continue;
        };
        feasible += 1;
        let e = r.explain.as_ref().expect("explained");
        let a = e.attribution.as_ref().expect("map attribution");
        assert_attribution_exact(a);
        assert!(e.audit.as_ref().is_some_and(|l| !l.phases.is_empty()), "{w}: audit empty");
        assert!(e.sensitivity.is_empty(), "{w}: sensitivity disabled for the figure");
    }
    assert!(feasible >= 1);
}

#[test]
fn random_grid_property_shares_are_nonnegative_and_sum_to_total() {
    let mut rng = dfmodel::util::prng::Rng::new(7);
    let chips = ["h100", "sn30", "tpuv4", "sn10"];
    let mems = ["ddr4", "hbm3"];
    let links = ["pcie4", "nvlink4"];
    let mut feasible = 0;
    for _ in 0..10 {
        let chip = rng.choice(&chips);
        let mem = rng.choice(&mems);
        let link = rng.choice(&links);
        let ring = [4usize, 8, 16][rng.below(3)];
        let batch = [16.0, 64.0, 256.0][rng.below(3)];
        let mut s = Scenario::llm("gpt3-175b")
            .batch(batch)
            .on(SystemCfg::new(chip, mem, link).ring(ring))
            .explained();
        s.explain.sensitivity = false;
        let Ok(r) = s.evaluate() else { continue };
        feasible += 1;
        let e = r.explain.expect("explained");
        let a = e.attribution.expect("map attribution");
        assert_attribution_exact(&a);
        assert_eq!(a.total, r.perf.as_ref().unwrap().step_time);
    }
    assert!(feasible >= 3, "grid too infeasible to exercise the property ({feasible}/10)");
}

#[test]
fn serve_explain_attributes_both_phases_and_audits_splits() {
    let s = Scenario::load(&scenario_dir().join("serve_sn40l.json")).expect("load scenario");
    let r = s.explained().evaluate().expect("feasible");
    let e = r.explain.as_ref().expect("explained");
    let a = e.attribution.as_ref().expect("serving attribution");
    assert_attribution_exact(a);
    assert_eq!(a.kernels.len(), 2, "prefill + decode rows");
    let audit = e.audit.as_ref().expect("serving split audit");
    let split = audit.phases.iter().find(|p| p.phase == "serving.split").expect("phase");
    assert!(split.considered >= 1, "alternative TP x PP splits weighed");
    assert!(split.best.is_some());
}

#[test]
fn explore_explain_tags_the_frontier() {
    let s = Scenario::load(&scenario_dir().join("explore_small.json")).expect("load scenario");
    let r = s.explained().evaluate().expect("explore runs");
    let e = r.explain.as_ref().expect("explained");
    let frontier = r.explore.as_ref().map_or(0, |x| x.frontier.len());
    assert_eq!(e.frontier_tags.len(), frontier.min(8), "one tag per reported frontier row");
    for t in &e.frontier_tags {
        assert!(t.contains("util") && t.contains("-bound"), "malformed tag '{t}'");
    }
    // an explore report explains the frontier, not one arbitrary candidate
    assert!(e.attribution.is_none(), "no per-candidate attribution leaks into explore");
    assert!(e.audit.is_none(), "no per-candidate audit leaks into explore");
}

#[test]
fn explain_options_roundtrip_through_scenario_json() {
    let mut s = Scenario::load(&scenario_dir().join("llm_dgx.json")).unwrap().explain_top(3);
    s.explain.sensitivity = false;
    let re = Scenario::parse(&s.to_json().pretty()).expect("parse back");
    assert_eq!(s, re, "explain options must round-trip");
    assert_eq!(re.explain.top, 3);
    assert!(!re.explain.sensitivity);
}

#[test]
fn render_tail_keeps_lint_before_the_stats_footer() {
    // ddr4 drained by nvlink4 draws the DF-S002 hierarchy warning; tracing
    // adds the span/metrics footer — the machine-parsed tail order is
    // lint warnings first, stats last, nothing after
    let mut s = Scenario::llm("gpt3-175b")
        .on(SystemCfg::new("sn10", "ddr4", "nvlink4").ring(8))
        .traced()
        .explained();
    s.explain.sensitivity = false;
    let r = s.evaluate().expect("feasible");
    let text = r.render();
    let lint = text.find("warning[DF-S002]").expect("hierarchy warning rendered");
    let attribution = text.find("attribution :").expect("explain section rendered");
    let stats = text.find("spans").expect("stats footer rendered");
    assert!(attribution < lint, "explain section stays above the machine-parsed tail");
    assert!(lint < stats, "lint warnings print before the span-tree/metrics footer");
}
