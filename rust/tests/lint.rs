//! Golden coverage for the `dfmodel lint` static checker: every rule has a
//! committed fixture in `examples/scenarios/bad/` that triggers exactly its
//! code, every committed (good) scenario lints clean, the `evaluate`
//! pre-flight gate blocks on errors (and only errors), and lint-clean
//! scenarios never panic the optimizer.

use dfmodel::api::{Scenario, SystemCfg};
use dfmodel::lint::{lint_json, LintReport};
use dfmodel::util::json::Json;
use std::path::{Path, PathBuf};

fn scenario_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

fn lint_file(path: &Path) -> LintReport {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    lint_json(&j)
}

/// One fixture per rule, each triggering exactly its own code.
#[test]
fn every_rule_has_a_fixture_that_triggers_exactly_it() {
    let golden: &[(&str, &str, bool)] = &[
        ("c001_unknown_chip.json", "DF-C001", true),
        ("g001_dangling.json", "DF-G001", true),
        ("g002_cycle.json", "DF-G002", true),
        ("g003_zero_tensor.json", "DF-G003", true),
        ("g004_bad_dims.json", "DF-G004", true),
        ("s001_negative_bandwidth.json", "DF-S001", true),
        ("s002_inverted_hierarchy.json", "DF-S002", false),
        ("s003_dims_vs_chips.json", "DF-S003", true),
        ("s004_power_outlier.json", "DF-S004", false),
        ("m001_forced_mismatch.json", "DF-M001", true),
        ("m002_split_mismatch.json", "DF-M002", true),
        ("m003_kv_overflow.json", "DF-M003", true),
        ("m004_sram_oversub.json", "DF-M004", false),
    ];
    for (file, code, is_error) in golden {
        let r = lint_file(&scenario_dir().join("bad").join(file));
        assert_eq!(r.codes(), vec![*code], "{file}: {:?}", r.diags);
        assert_eq!(r.has_errors(), *is_error, "{file}: {:?}", r.diags);
        assert!(!r.is_clean(), "{file} should not be clean");
    }
}

/// No rule fires on a bad fixture without a golden entry: the directory
/// holds exactly the files the table above names.
#[test]
fn bad_fixture_directory_matches_the_golden_table() {
    let mut files: Vec<String> = std::fs::read_dir(scenario_dir().join("bad"))
        .expect("bad fixture dir")
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    files.sort();
    assert_eq!(files.len(), 13, "{files:?}");
}

/// Every committed example scenario stays lint-clean (no errors, no
/// warnings) — the same invariant CI enforces via `dfmodel lint`.
#[test]
fn committed_scenarios_lint_clean() {
    let mut checked = 0;
    for entry in std::fs::read_dir(scenario_dir()).expect("scenario dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let r = lint_file(&path);
        assert!(r.is_clean(), "{}: {}", path.display(), r.render());
        checked += 1;
    }
    assert!(checked >= 3, "expected the committed example scenarios, found {checked}");
}

/// The evaluate pre-flight: errors abort with the diagnostics in the
/// message; `no_lint` opts out and falls through to the optimizer's own
/// (lint-free) error.
#[test]
fn evaluate_gate_blocks_on_errors_and_no_lint_opts_out() {
    let s = Scenario::load(&scenario_dir().join("bad/m001_forced_mismatch.json")).expect("load");
    let e = s.evaluate().expect_err("lint gate should block").to_string();
    assert!(e.contains("DF-M001"), "{e}");
    assert!(e.contains("scenario fails lint"), "{e}");
    let e = s.no_lint().evaluate().expect_err("still infeasible").to_string();
    assert!(!e.contains("lint"), "{e}");
}

/// Warning-only findings do not block; they ride along on the report
/// (render + JSON) instead.
#[test]
fn warnings_ride_along_on_the_report() {
    let s = Scenario::load(&scenario_dir().join("bad/s002_inverted_hierarchy.json")).expect("load");
    let r = s.evaluate().expect("warnings must not block evaluation");
    assert!(r.lint.n_warnings() >= 1 && r.lint.n_errors() == 0, "{}", r.lint.render());
    assert!(r.render().contains("warning[DF-S002]"), "{}", r.render());
    assert!(r.to_json().get("lint").is_some());
}

/// The `lint` field round-trips through JSON, and stays out of the JSON
/// when it has its default value.
#[test]
fn no_lint_roundtrips_through_json() {
    let s = Scenario::llm("gpt3-175b");
    assert!(s.to_json().get("lint").is_none());
    let s = s.no_lint();
    let text = s.to_json().pretty();
    assert_eq!(Scenario::parse(&text).expect("reparse"), s);
}

/// Property: over a small catalog grid, a scenario that lints with no
/// errors never panics the optimizer — `evaluate` returns Ok or a clean
/// Err, both acceptable.
#[test]
fn lint_clean_scenarios_never_panic_the_optimizer() {
    for chip in ["sn10", "h100"] {
        for mem in ["ddr4", "hbm3"] {
            for link in ["pcie4", "nvlink4"] {
                for chips in [4usize, 8] {
                    let s = Scenario::llm("gpt3-175b")
                        .batch(64.0)
                        .on(SystemCfg::new(chip, mem, link).ring(chips));
                    let lint = dfmodel::lint::lint_scenario(&s);
                    assert!(!lint.has_errors(), "{chip}/{mem}/{link}: {}", lint.render());
                    let _ = s.evaluate(); // must not panic
                }
            }
        }
    }
}
