//! Integration: load the AOT artifacts (built by `make artifacts`), compile
//! them on the default backend (the pure-Rust HLO interpreter — no PJRT,
//! no network), execute every mapping variant, and check numerics against
//! the Python oracle — the full L1→L2→L3 stack, offline.
//!
//! Skipped (with a notice) when artifacts/ has not been built.

use dfmodel::runtime::{find_artifacts, Runtime};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let found = find_artifacts();
    if found.is_none() {
        eprintln!("artifacts/ not built; run `make artifacts` — skipping");
    }
    found
}

#[test]
fn all_pipelines_match_the_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir, &[]).expect("load all artifacts");
    assert_eq!(rt.platform(), "interp", "default backend must be the interpreter");
    let tol = rt.manifest.tolerance.max(1e-3);
    for name in ["fused", "kernel_by_kernel", "vendor", "dfmodel"] {
        let err = rt.verify_pipeline(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(err < tol, "{name}: max err {err} > tol {tol}");
    }
}

#[test]
fn dataflow_mappings_move_less_intermediate_data() {
    // the Fig. 2C vs 2D contrast, measured on real execution: the fused
    // mapping's host-visible intermediate traffic is far below the
    // kernel-by-kernel mapping's.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir, &["fused", "kernel_by_kernel", "vendor"]).expect("load");
    let x = rt.reference_input().unwrap();
    let (_, fused) = rt.run_pipeline("fused", &x).unwrap();
    let (_, kbk) = rt.run_pipeline("kernel_by_kernel", &x).unwrap();
    let (_, vendor) = rt.run_pipeline("vendor", &x).unwrap();
    assert!(
        fused.intermediate_bytes * 4.0 < kbk.intermediate_bytes,
        "fused {} vs kbk {}",
        fused.intermediate_bytes,
        kbk.intermediate_bytes
    );
    assert!(vendor.intermediate_bytes < kbk.intermediate_bytes);
    assert_eq!(kbk.steps, 14);
    assert_eq!(vendor.steps, 4);
}

#[test]
fn pipelines_agree_with_each_other() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir, &["vendor", "dfmodel"]).expect("load");
    let x = rt.reference_input().unwrap();
    let (a, _) = rt.run_pipeline("vendor", &x).unwrap();
    let (b, _) = rt.run_pipeline("dfmodel", &x).unwrap();
    let max_err =
        a.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "vendor vs dfmodel diverge: {max_err}");
}

#[test]
fn runtime_rejects_bad_input_length() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir, &["fused"]).expect("load");
    assert!(rt.run_pipeline("fused", &[0.0; 3]).is_err());
    assert!(rt.run_pipeline("does-not-exist", &[0.0; 3]).is_err());
}

#[test]
fn unknown_pipeline_and_artifact_error_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let e = Runtime::load(&dir, &["no-such-pipeline"]).unwrap_err();
    assert!(e.to_string().contains("no-such-pipeline"), "{e}");
}
