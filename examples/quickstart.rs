//! Quickstart: build a workload dataflow graph, describe a system, run both
//! DFModel optimization passes, and print the resulting mapping.
//!
//!     cargo run --release --example quickstart

use dfmodel::graph::gpt::{gpt3_175b, gpt_layer_graph};
use dfmodel::interchip::{self, InterChipOptions};
use dfmodel::intrachip::{self, IntraChipOptions};
use dfmodel::system::{chip, interconnect, memory, topology, SystemSpec};
use dfmodel::util::units::fmt_time;

fn main() {
    // 1. the workload: one GPT3-175B transformer layer (Fig. 2A, 14 kernels)
    let cfg = gpt3_175b();
    let graph = gpt_layer_graph(&cfg, 1.0);
    println!("workload: {}", graph.summary());

    // 2. the system: 8 SambaNova SN10 RDUs on a PCIe ring (§VII)
    let link = interconnect::pcie4();
    let sys = SystemSpec::new(
        chip::sn10(),
        memory::ddr4(),
        link.clone(),
        topology::ring(8, &link),
    );
    println!("system:   {}", sys.describe());

    // 3. inter-chip pass (§IV): TP/PP/DP + sharding + stages
    let inter = interchip::optimize(&graph, &sys, &InterChipOptions::default())
        .expect("feasible inter-chip mapping");
    println!(
        "\ninter-chip: {} | critical time {} | explored O(10^{:.0}) mappings",
        inter.plan.describe(),
        fmt_time(inter.t_cri),
        inter.space_log10
    );

    // 4. intra-chip pass (§V): fuse kernels into on-chip partitions
    let (sharded, net_time) =
        interchip::shard_graph(&graph, &sys, &inter.plan, &inter.scheme_idx);
    let intra = intrachip::optimize_intra(
        &sharded,
        &sys.chip,
        &sys.memory,
        &IntraChipOptions { net_time, ..Default::default() },
    )
    .expect("feasible intra-chip mapping");

    println!("intra-chip: {} fused partitions, per-input time {}", intra.assignment.n_used(),
        fmt_time(intra.total_time));
    for (i, names) in intra.partition_names(&sharded).iter().enumerate() {
        println!("  partition {i}: {}", names.join(", "));
    }
    let (c, m, n) = intra.breakdown();
    println!(
        "breakdown: compute {} | memory {} | network {}",
        fmt_time(c),
        fmt_time(m),
        fmt_time(n)
    );
}
