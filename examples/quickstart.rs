//! Quickstart: describe a scenario with the builder API, evaluate it, and
//! read the report — then peel the facade back one level and run the two
//! optimization passes (§IV inter-chip, §V intra-chip) by hand.
//!
//!     cargo run --release --example quickstart

use dfmodel::api::{self, Scenario, SystemCfg};
use dfmodel::graph::gpt::{gpt3_175b, gpt_layer_graph};
use dfmodel::interchip::{self, InterChipOptions};
use dfmodel::intrachip::IntraChipOptions;
use dfmodel::util::units::fmt_time;

fn main() {
    // ---- 1. the facade: one scenario in, one report out ----
    // GPT3-175B training on 8 SambaNova SN10 RDUs on a PCIe ring (§VII)
    let scenario = Scenario::llm("gpt3-175b")
        .batch(64.0)
        .on(SystemCfg::new("sn10", "ddr4", "pcie4").ring(8));
    let report = scenario.evaluate().expect("feasible mapping");
    print!("{}", report.render());
    println!("(as JSON: every field of `report.to_json()` is stable)\n");

    // the same scenario round-trips through JSON — save it, ship it, rerun
    // it with `dfmodel optimize --scenario my.json`
    let text = scenario.to_json().pretty();
    assert_eq!(Scenario::parse(&text).unwrap(), scenario);

    // ---- 2. under the facade: the two passes on one layer graph ----
    let cfg = gpt3_175b();
    let graph = gpt_layer_graph(&cfg, 1.0);
    let sys = SystemCfg::new("sn10", "ddr4", "pcie4").ring(8).build().unwrap();
    println!("workload: {}", graph.summary());
    println!("system:   {}", sys.describe());

    // inter-chip pass (§IV): TP/PP/DP + sharding + stages
    let inter = api::map_graph(&graph, &sys, &InterChipOptions::default())
        .expect("feasible inter-chip mapping");
    println!(
        "\ninter-chip: {} | critical time {} | explored O(10^{:.0}) mappings",
        inter.plan.describe(),
        fmt_time(inter.t_cri.raw()),
        inter.space_log10
    );

    // intra-chip pass (§V): fuse kernels into on-chip partitions
    let (sharded, net_time) =
        interchip::shard_graph(&graph, &sys, &inter.plan, &inter.scheme_idx);
    let intra = api::map_chip(
        &sharded,
        &sys.chip,
        &sys.memory,
        &IntraChipOptions { net_time, ..Default::default() },
    )
    .expect("feasible intra-chip mapping");

    println!(
        "intra-chip: {} fused partitions, per-input time {}",
        intra.assignment.n_used(),
        fmt_time(intra.total_time)
    );
    for (i, names) in intra.partition_names(&sharded).iter().enumerate() {
        println!("  partition {i}: {}", names.join(", "));
    }
    let (c, m, n) = intra.breakdown();
    println!(
        "breakdown: compute {} | memory {} | network {}",
        fmt_time(c),
        fmt_time(m),
        fmt_time(n)
    );
}
