//! Design-space exploration for GPT3 1T training on 1024 accelerators
//! (Figs 10/11): 4 chips × 5 topologies × 4 memory/interconnect combos.
//!
//!     cargo run --release --example dse_llm

fn main() {
    println!("{}", dfmodel::figures::dse_figs::dse_figure(dfmodel::dse::Workload::Llm));
    println!("CSV written to results/fig10.csv");
}
