//! Fabric-simulator walkthrough: expand a 4×4 torus into its link graph,
//! race the four collective-algorithm families against the analytical α-β
//! model, then calibrate an 8-chip ring system and re-run the inter-chip
//! optimizer with simulation-backed collective costs.
//!
//!     cargo run --release --example fabric_sim

use dfmodel::api;
use dfmodel::collective::{self, Collective, CollectiveModel};
use dfmodel::fabric::{self, CalibrateOpts, FabricGraph, SimConfig};
use dfmodel::graph::gpt::{gpt3_175b, gpt_layer_graph};
use dfmodel::interchip::InterChipOptions;
use dfmodel::system::{chip, interconnect, memory, topology, Dim, SystemSpec};
use dfmodel::util::units::{fmt_time, Bytes};

fn main() {
    // ---- 1. algorithm race on a 4×4 torus ----
    let link = interconnect::nvlink4();
    let topo = topology::torus2d(4, 4, &link);
    let g = FabricGraph::new(&topo);
    let group: Vec<usize> = (0..16).collect();
    let dims: Vec<&Dim> = topo.dims.iter().collect();
    let cfg = SimConfig::default();
    println!(
        "== {} | {} links | bisection {:.1} TB/s ==",
        topo.name,
        g.links.len(),
        topo.bisection_bytes_per_s().raw() / 1e12
    );
    for bytes in [32e3, 256e6] {
        let ana = collective::time_hier(Collective::AllReduce, Bytes::new(bytes), &dims).raw();
        println!("AllReduce {:.3} MB/chip (analytical {}):", bytes / 1e6, fmt_time(ana));
        for e in fabric::evaluate_algos(&g, &group, Collective::AllReduce, bytes, &cfg) {
            println!(
                "  {:<6} {:>12}  ({:+.1}% vs analytical, max link {:.0}%)",
                e.algo.name(),
                fmt_time(e.time),
                (e.time / ana - 1.0) * 100.0,
                e.max_link_util * 100.0
            );
        }
    }

    // ---- 2. calibrate a system and re-optimize the GPT mapping ----
    let plink = interconnect::pcie4();
    let sys = SystemSpec::new(
        chip::sn10(),
        memory::ddr4(),
        plink.clone(),
        topology::ring(8, &plink),
    );
    let cal_sys = api::calibrate(&sys, &CalibrateOpts::default());
    if let CollectiveModel::Calibrated(c) = &cal_sys.collective_model {
        println!("\ncalibrated {} (collective × dim-group) tables", c.len());
    }
    let gr = gpt_layer_graph(&gpt3_175b(), 1.0);
    let opts = InterChipOptions { force_degrees: Some((8, 1, 1)), ..Default::default() };
    let ana = api::map_graph(&gr, &sys, &opts).expect("analytical mapping");
    let cal = api::map_graph(&gr, &cal_sys, &opts).expect("calibrated mapping");
    println!("GPT3-175B layer on 8×SN10 ring, TP=8:");
    println!("  analytical model : t_cri {}", fmt_time(ana.t_cri.raw()));
    println!("  calibrated model : t_cri {}", fmt_time(cal.t_cri.raw()));
    println!(
        "  (simulation-certified collective costs shift the bound by {:+.1}%)",
        (cal.t_cri / ana.t_cri - 1.0) * 100.0
    );
}
