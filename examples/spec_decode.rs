//! Speculative-decoding case study (§VIII-B, Fig. 21): sequence- and
//! tree-based schemes, drafts {68M, 8B, 70B} → target Llama3 405B on
//! 16 SN40L, sweeping window size and acceptance rate.
//!
//!     cargo run --release --example spec_decode

fn main() {
    println!("{}", dfmodel::figures::serving_figs::fig21());
}
