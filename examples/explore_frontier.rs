//! Pareto-frontier exploration of the §VI-C design space through the
//! facade: GPT3-1T training over the paper grid extended with a batch
//! axis, pruned by the roofline bound.
//!
//!     cargo run --release --example explore_frontier

use dfmodel::api::{ExploreOptions, Scenario};

fn main() {
    let opts = ExploreOptions {
        batches: vec![None, Some(4096.0)],
        top: 12,
        ..Default::default()
    };
    let scenario = Scenario::llm("gpt3-1t").batch(2048.0).explore(opts);
    match scenario.evaluate() {
        Ok(report) => print!("{}", report.render()),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    }
}
