//! LLM serving case study (§VIII-A, Fig. 20): Llama3 8B on 16 SN40L RDUs —
//! TTFT / TPOT / throughput across TP×PP splits, validated against the
//! measured 1100 tok/s decode at TP=16.
//!
//!     cargo run --release --example serving_llama

fn main() {
    println!("{}", dfmodel::figures::serving_figs::fig20());
}
