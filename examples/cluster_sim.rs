//! Cluster serving walkthrough: bursty traffic for Llama3-8B on one
//! 16×SN40L replica (the §VIII-A platform), simulated with continuous
//! batching and KV admission control, then the capacity planner picks the
//! cheapest Llama3-70B fleet for 2 requests/s under SLOs.
//!
//!     cargo run --release --example cluster_sim

use dfmodel::cluster::engine::{simulate, ReplicaConfig, Slo};
use dfmodel::cluster::planner::{plan, render, PlanTarget, PlanTraffic};
use dfmodel::cluster::workload::{Arrivals, LengthDist, TraceSpec};
use dfmodel::graph::llama::{llama3_70b, llama3_8b};
use dfmodel::serving::sn40l_x16;

fn main() {
    // ---- 1. one replica under a bursty diurnal cycle ----
    let cfg = ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1);
    let spec = TraceSpec {
        seed: 17,
        n_requests: 400,
        arrivals: Arrivals::Bursty { base: 2.0, peak: 14.0, period: 60.0 },
        prompt: LengthDist { mean: 1024.0, sigma: 0.4, min: 16, max: 8192 },
        output: LengthDist { mean: 128.0, sigma: 0.6, min: 2, max: 2048 },
    };
    let slo = Slo { ttft: 1.0, tpot: 0.02 };
    println!("== Llama3 8B on 16xSN40L, bursty 2..14 rps ==");
    let report = simulate(&cfg, 1, &spec.generate(), &slo).expect("feasible");
    print!("{}", report.render());

    // ---- 2. capacity planning for Llama3-70B at 2 rps ----
    let target = PlanTarget { qps: 2.0, slo: Slo { ttft: 2.0, tpot: 0.05 }, attainment: 0.9 };
    let res = plan(&llama3_70b(), &target, &PlanTraffic::default());
    println!();
    print!("{}", render(&res, 10));
    if let Some(i) = res.best {
        let c = &res.candidates[i];
        println!(
            "cheapest fleet: {} x{} TP{}xPP{} x {} replicas @ ${:.2}/hr",
            c.platform, c.group, c.tp, c.pp, c.replicas, c.usd_per_hour
        );
    }
}
