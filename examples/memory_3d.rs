//! 3-D memory case study (§VIII-C, Fig. 22): training a projected 100T GPT
//! on 1024 SN40L-class chips whose die area is split between compute tiles
//! and SRAM, under 2-D DDR / 2.5-D HBM / 3-D-stacked memory.
//!
//!     cargo run --release --example memory_3d

fn main() {
    println!("{}", dfmodel::figures::serving_figs::fig22());
}
