//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! 1. DFModel (L3) optimizes the intra-chip mapping of a small GPT layer
//!    and *predicts* the ranking of four mapping variants (non-dataflow
//!    kernel-by-kernel, vendor 4-partition, DFModel-optimized, fused).
//! 2. The same four mappings are then *executed for real*: the AOT
//!    artifacts (L2 JAX model + L1 Pallas kernels, lowered to HLO text by
//!    `make artifacts`) run on the default runtime backend — the pure-Rust
//!    HLO interpreter (or PJRT with `--features pjrt`).
//! 3. Numerics are verified against the Python oracle and the measured
//!    intermediate-traffic ordering is compared with the model's
//!    prediction — proving all layers compose.
//!
//!     make artifacts && cargo run --release --example e2e_gpt_mapping

use dfmodel::api;
use dfmodel::graph::gpt::{gpt_layer_graph, GptConfig};
use dfmodel::intrachip::IntraChipOptions;
use dfmodel::runtime::{find_artifacts, Runtime};
use dfmodel::system::{chip, memory};
use dfmodel::util::table::Table;

fn main() {
    let Some(dir) = find_artifacts() else {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    };
    let rt = Runtime::load(&dir, &[]).expect("load artifacts");
    println!("runtime backend: {}\n", rt.platform());
    let m = &rt.manifest;

    // ---- model the same tiny layer the artifacts implement ----
    let cfg = GptConfig {
        layers: 1,
        d_model: m.d_model as f64,
        n_heads: m.n_heads as f64,
        seq: m.seq as f64,
        d_ff: m.d_ff as f64,
        vocab: 1.0,
        dtype_bytes: 4.0, // artifacts are f32
    };
    let graph = gpt_layer_graph(&cfg, 1.0);
    // a small dataflow chip so the tiny layer still has interesting
    // SRAM pressure; DDR-class memory
    let mut small_chip = chip::sn10();
    small_chip.sram_bytes = dfmodel::util::units::Bytes::new(2e6);
    let mem = memory::ddr4();

    // model each variant with the SAME partitioning the artifacts execute
    let model = |force_kbk: bool, part_of: Option<fn(&str) -> usize>| {
        let mut opts = IntraChipOptions { force_kernel_by_kernel: force_kbk, ..Default::default() };
        if let Some(f) = part_of {
            opts.force_assignment =
                Some(graph.kernels.iter().map(|k| f(&k.name)).collect());
        }
        api::map_chip(&graph, &small_chip, &mem, &opts).expect("feasible")
    };
    let kbk_model = model(true, None);
    let vendor_model = model(false, Some(dfmodel::figures::casestudy::vendor_partition_of));
    let dfm_model = model(false, Some(dfmodel::figures::casestudy::dfmodel_partition_of));

    // ---- execute the real pipelines ----
    let x = rt.reference_input().expect("input");
    let mut t = Table::new(
        "modeled (analytical) vs executed (runtime backend) — tiny GPT layer",
        &[
            "mapping",
            "modeled partitions",
            "modeled DRAM bytes",
            "executed steps",
            "measured intermediates",
            "max |err| vs oracle",
            "wall",
        ],
    );
    let mut measured = Vec::new();
    for (name, modeled) in [
        ("kernel_by_kernel", Some(&kbk_model)),
        ("vendor", Some(&vendor_model)),
        ("dfmodel", Some(&dfm_model)),
        ("fused", None),
    ] {
        let (_, stats) = rt.run_pipeline(name, &x).expect(name);
        let err = rt.verify_pipeline(name).expect(name);
        measured.push((name, stats.intermediate_bytes));
        t.row(&[
            name.to_string(),
            modeled.map_or("-".into(), |mm| format!("{}", mm.assignment.n_used())),
            modeled.map_or("-".into(), |mm| format!("{:.0}", mm.total_dram_traffic())),
            format!("{}", stats.steps),
            format!("{:.0}", stats.intermediate_bytes),
            format!("{err:.2e}"),
            format!("{:?}", stats.wall),
        ]);
    }
    println!("{}", t.render());

    // ---- the headline check: model predicts the measured traffic order ----
    let modeled_order = [
        ("kernel_by_kernel", kbk_model.total_dram_traffic()),
        ("vendor", vendor_model.total_dram_traffic()),
        ("dfmodel", dfm_model.total_dram_traffic()),
    ];
    println!("modeled DRAM-traffic ranking (worst to best):");
    let mut mo = modeled_order.to_vec();
    mo.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (n, v) in &mo {
        println!("  {n:<18} {v:.0} B");
    }
    println!("measured intermediate-traffic ranking (worst to best):");
    let mut me: Vec<_> =
        measured.iter().filter(|(n, _)| *n != "fused").cloned().collect();
    me.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (n, v) in &me {
        println!("  {n:<18} {v:.0} B");
    }
    let agree = mo.iter().map(|(n, _)| *n).eq(me.iter().map(|(n, _)| *n));
    println!(
        "\nmodel/measurement ranking agreement: {}",
        if agree { "YES — all layers compose" } else { "NO (see table)" }
    );
    let fused = measured.iter().find(|(n, _)| *n == "fused").unwrap().1;
    let kbk = measured.iter().find(|(n, _)| *n == "kernel_by_kernel").unwrap().1;
    println!("fused vs kernel-by-kernel measured traffic: {:.1}x less", kbk / fused);
    if !agree {
        std::process::exit(1);
    }
}
