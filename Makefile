# Convenience targets; the committed artifacts/ match the `artifacts` recipe.

ARTIFACT_FLAGS ?= --d-model 64 --n-heads 2 --seq 128 --d-ff 256

.PHONY: build test bench artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench paper_figures
	cargo bench --bench ablations
	cargo bench --bench optimizer_perf

# Regenerate the AOT HLO artifacts (requires JAX; see python/compile/aot.py)
artifacts:
	cd python && python3 -m compile.aot --outdir ../artifacts $(ARTIFACT_FLAGS)
